//! Property-test battery over the coordinator invariants (DESIGN.md §6),
//! using the in-repo seeded harness (`k2m::testing::prop`) — replay any
//! failure with `PROP_SEED=<seed> cargo test <name>`.

use k2m::cluster::{elkan, k2means, lloyd, Config};
use k2m::core::kernels::quant::{self, QuantPair, QuantRow, QuantizedCodes};
use k2m::core::{ops, Matrix, NumericsMode, OpCounter};
use k2m::init::split::{projective_split, sqnorms};
use k2m::init::{gdi, kmeans_pp, random_init, GdiOpts};
use k2m::knn::{knn_graph, KdTree};
use k2m::metrics::{energy, phi};
use k2m::rng::Pcg32;
use k2m::testing::prop::{check, small_usize};

fn random_data(rng: &mut Pcg32, n: usize, d: usize) -> Matrix {
    let mut m = Matrix::zeros(n, d);
    for i in 0..n {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32() * (1.0 + (i % 3) as f32);
        }
    }
    m
}

#[test]
fn prop_lloyd_energy_monotone() {
    check("lloyd energy monotone", 30, |rng| {
        let n = small_usize(rng, 20, 200);
        let d = small_usize(rng, 1, 16);
        let k = small_usize(rng, 1, n.min(20));
        let x = random_data(rng, n, d);
        let init = random_init(&x, k, rng.next_u64());
        let mut c = OpCounter::default();
        let r = lloyd(&x, &init, &Config { k, max_iters: 30, ..Default::default() }, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(
                w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()),
                "energy rose {} -> {}",
                w[0].energy,
                w[1].energy
            );
        }
    });
}

#[test]
fn prop_k2means_energy_monotone_and_valid() {
    check("k2means monotone+valid", 30, |rng| {
        let n = small_usize(rng, 30, 250);
        let d = small_usize(rng, 1, 12);
        let k = small_usize(rng, 2, n.min(24));
        let kn = small_usize(rng, 1, k + 1).min(k);
        let x = random_data(rng, n, d);
        let mut c = OpCounter::default();
        let init = gdi(&x, k, &mut c, rng.next_u64(), &GdiOpts::default());
        let cfg = Config { k, kn, max_iters: 30, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        assert!(r.labels.iter().all(|&l| (l as usize) < k));
        for w in r.trace.points.windows(2) {
            assert!(
                w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()),
                "energy rose {} -> {} (k={k} kn={kn})",
                w[0].energy,
                w[1].energy
            );
        }
    });
}

#[test]
fn prop_elkan_equals_lloyd() {
    check("elkan == lloyd", 25, |rng| {
        let n = small_usize(rng, 20, 150);
        let d = small_usize(rng, 1, 10);
        let k = small_usize(rng, 1, n.min(15));
        let x = random_data(rng, n, d);
        let init = random_init(&x, k, rng.next_u64());
        let cfg = Config { k, max_iters: 25, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let re = elkan(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, re.labels, "n={n} d={d} k={k}");
    });
}

#[test]
fn prop_k2means_full_kn_equals_lloyd() {
    check("k2means(kn=k) == lloyd", 20, |rng| {
        let n = small_usize(rng, 20, 120);
        let d = small_usize(rng, 1, 8);
        let k = small_usize(rng, 2, n.min(12));
        let x = random_data(rng, n, d);
        let mut c0 = OpCounter::default();
        let init = kmeans_pp(&x, k, &mut c0, rng.next_u64());
        let cfg2 = Config { k, kn: k, max_iters: 25, ..Default::default() };
        let cfgl = Config { k, max_iters: 25, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let r2 = k2means(&x, &init, &cfg2, &mut c1);
        let rl = lloyd(&x, &init, &cfgl, &mut c2);
        assert_eq!(r2.labels, rl.labels, "n={n} d={d} k={k}");
    });
}

#[test]
fn prop_kdtree_exact_when_unbounded() {
    check("kdtree exact", 30, |rng| {
        let n = small_usize(rng, 5, 300);
        let d = small_usize(rng, 1, 20);
        let pts = random_data(rng, n, d);
        let mut c = OpCounter::default();
        let tree = KdTree::build(&pts, rng.next_u64(), &mut c);
        for _ in 0..10 {
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32() * 2.0).collect();
            let (gi, gd) = tree.nearest(&q, usize::MAX, &mut c);
            // Brute force.
            let mut best = (u32::MAX, f32::INFINITY);
            for i in 0..n {
                let dist = ops::sqdist_raw(&q, pts.row(i));
                if dist < best.1 {
                    best = (i as u32, dist);
                }
            }
            assert!((gd - best.1).abs() <= 1e-4 * (1.0 + best.1), "dist mismatch");
            let _ = gi;
        }
    });
}

#[test]
fn prop_knn_graph_matches_bruteforce() {
    check("knn graph exact", 25, |rng| {
        let k = small_usize(rng, 2, 40);
        let kn = small_usize(rng, 1, k + 1).min(k);
        let d = small_usize(rng, 1, 12);
        let c = random_data(rng, k, d);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, kn, &mut ctr);
        for i in 0..k {
            let mut all: Vec<(f32, u32)> =
                (0..k).map(|j| (ops::sqdist_raw(c.row(i), c.row(j)), j as u32)).collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            // Compare distance multisets (ties may reorder indices).
            let want: Vec<f32> = all[..kn].iter().map(|&(dv, _)| dv).collect();
            let mut got: Vec<f32> = g.dists_row(i).to_vec();
            got.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for (gv, wv) in got.iter().zip(&want) {
                assert!((gv - wv).abs() <= 1e-4 * (1.0 + wv), "row {i}");
            }
        }
    });
}

#[test]
fn prop_lemma1_identity() {
    // Lemma 1: sum ||x - z||^2 = phi(S) + |S| * ||z - mu||^2
    check("lemma 1", 40, |rng| {
        let n = small_usize(rng, 1, 60);
        let d = small_usize(rng, 1, 10);
        let x = random_data(rng, n, d);
        let members: Vec<u32> = (0..n as u32).collect();
        let z: Vec<f32> = (0..d).map(|_| rng.gaussian_f32() * 3.0).collect();
        let lhs: f64 = (0..n).map(|i| ops::sqdist_raw(x.row(i), &z) as f64).sum();
        // mu
        let mut mu = vec![0.0f64; d];
        for i in 0..n {
            for (m, &v) in mu.iter_mut().zip(x.row(i)) {
                *m += v as f64;
            }
        }
        for m in mu.iter_mut() {
            *m /= n as f64;
        }
        let z_mu: f64 = mu.iter().zip(&z).map(|(&m, &zv)| (m - zv as f64).powi(2)).sum();
        let rhs = phi(&x, &members) + n as f64 * z_mu;
        assert!(
            (lhs - rhs).abs() <= 1e-3 * (1.0 + lhs.abs()),
            "lemma1: {lhs} vs {rhs} (n={n} d={d})"
        );
    });
}

#[test]
fn prop_split_phis_exact_and_partition() {
    check("projective split invariants", 30, |rng| {
        let n = small_usize(rng, 2, 120);
        let d = small_usize(rng, 1, 10);
        let x = random_data(rng, n, d);
        let members: Vec<u32> = (0..n as u32).collect();
        let mut c = OpCounter::default();
        let sq = sqnorms(&x, &mut c);
        let mut srng = Pcg32::seeded(rng.next_u64());
        let s = projective_split(&x, &members, 2, &sq, &mut c, &mut srng, 1, NumericsMode::Strict)
            .unwrap();
        // Partition.
        let mut all: Vec<u32> = s.left.iter().chain(&s.right).copied().collect();
        all.sort_unstable();
        assert_eq!(all, members);
        // Scan phis equal direct recomputation.
        let wl = phi(&x, &s.left);
        let wr = phi(&x, &s.right);
        assert!((s.phi_left - wl).abs() <= 1e-3 * (1.0 + wl), "{} vs {wl}", s.phi_left);
        assert!((s.phi_right - wr).abs() <= 1e-3 * (1.0 + wr), "{} vs {wr}", s.phi_right);
        // Split never increases energy vs unsplit.
        assert!(wl + wr <= phi(&x, &members) + 1e-4 * (1.0 + wl + wr));
    });
}

#[test]
fn prop_gdi_invariants() {
    check("gdi invariants", 25, |rng| {
        let n = small_usize(rng, 5, 200);
        let d = small_usize(rng, 1, 10);
        let k = small_usize(rng, 1, n + 1).min(n);
        let x = random_data(rng, n, d);
        let mut c = OpCounter::default();
        let init = gdi(&x, k, &mut c, rng.next_u64(), &GdiOpts::default());
        let labels = init.labels.unwrap();
        // k clusters, all non-empty, every point assigned.
        let mut counts = vec![0usize; k];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&ct| ct > 0), "empty cluster (n={n} k={k})");
        // Centers are member means.
        for j in 0..k {
            let members: Vec<u32> =
                (0..n as u32).filter(|&i| labels[i as usize] == j as u32).collect();
            let mut mean = vec![0.0f64; d];
            for &i in &members {
                for (m, &v) in mean.iter_mut().zip(x.row(i as usize)) {
                    *m += v as f64;
                }
            }
            for (dim, m) in mean.iter().enumerate() {
                let want = (m / members.len() as f64) as f32;
                let got = init.centers.row(j)[dim];
                assert!(
                    (got - want).abs() <= 1e-3 * (1.0 + want.abs()),
                    "cluster {j} dim {dim}: {got} vs {want}"
                );
            }
        }
        // Total energy decomposes into cluster phis.
        let e = energy(&x, &init.centers, &labels);
        let mut phis = 0.0;
        for j in 0..k as u32 {
            let members: Vec<u32> = (0..n as u32).filter(|&i| labels[i as usize] == j).collect();
            phis += phi(&x, &members);
        }
        assert!((e - phis).abs() <= 1e-3 * (1.0 + e));
    });
}

#[test]
fn prop_opcounter_lloyd_exact_count() {
    check("lloyd op count", 20, |rng| {
        let n = small_usize(rng, 10, 100);
        let d = small_usize(rng, 1, 8);
        let k = small_usize(rng, 1, n.min(10));
        let x = random_data(rng, n, d);
        let init = random_init(&x, k, rng.next_u64());
        let iters = small_usize(rng, 1, 4);
        let mut c = OpCounter::default();
        let r = lloyd(&x, &init, &Config { k, max_iters: iters, ..Default::default() }, &mut c);
        // Exactly n*k distances per executed assignment pass.
        assert_eq!(c.distances, (n * k * r.iters) as u64);
        // One addition per point per executed update step.
        assert!(c.additions <= (n * r.iters) as u64);
    });
}

#[test]
fn prop_update_never_increases_energy() {
    check("update step decreases energy", 30, |rng| {
        let n = small_usize(rng, 10, 150);
        let d = small_usize(rng, 1, 10);
        let k = small_usize(rng, 1, n.min(12));
        let x = random_data(rng, n, d);
        let centers = random_init(&x, k, rng.next_u64()).centers;
        // Arbitrary (valid) labels.
        let labels: Vec<u32> = (0..n).map(|_| rng.gen_below(k) as u32).collect();
        let e0 = energy(&x, &centers, &labels);
        let mut c = OpCounter::default();
        let (new_centers, _) = k2m::cluster::update_means(&x, &labels, &centers, &mut c);
        let e1 = energy(&x, &new_centers, &labels);
        assert!(e1 <= e0 + 1e-3 * (1.0 + e0), "{e1} > {e0}");
    });
}

// -------------------------------------------------------------------------
// Quantized tier (core::kernels::quant): the prune/re-rank invariants
// under a dimension sweep that crosses every 64-bit word and tail-bit
// boundary.
// -------------------------------------------------------------------------

/// Dimension generator for the quantized properties: half the draws hit
/// the packing boundary cases (empty, single word, word edges, long
/// tails) exactly, the other half sweep `0..201` so three-word rows and
/// odd tails all occur.
fn quant_dim(rng: &mut Pcg32) -> usize {
    const DIMS: [usize; 12] = [0, 1, 31, 63, 64, 65, 100, 127, 128, 129, 192, 200];
    if small_usize(rng, 0, 2) == 0 {
        DIMS[small_usize(rng, 0, DIMS.len())]
    } else {
        small_usize(rng, 0, 201)
    }
}

/// Half the quantized property cases sharpen the data to near-binary ±1
/// patterns — the regime where the certified bounds actually separate
/// and the prune path (not just the fall-through) gets exercised.
fn maybe_sharpen(rng: &mut Pcg32, m: &mut Matrix) {
    if small_usize(rng, 0, 2) == 0 {
        for v in m.as_mut_slice() {
            *v = v.signum() + 1e-3 * *v;
        }
    }
}

#[test]
fn prop_quant_pack_roundtrip_invariants() {
    check("quant pack invariants", 40, |rng| {
        let d = quant_dim(rng);
        let n = small_usize(rng, 1, 20);
        let mut x = random_data(rng, n, d);
        maybe_sharpen(rng, &mut x);
        let mu = quant::column_means(&x);
        let codes = QuantizedCodes::pack(&x, &mu);
        assert_eq!((codes.rows(), codes.dim()), (n, d));
        assert_eq!(codes.words(), quant::words_for(d));
        assert_eq!(codes.bits().len(), n * codes.words());
        for i in 0..n {
            let row = codes.row_q(i);
            // Sign bits are exactly the signs of the centered coords,
            // little-endian within each word.
            for j in 0..d {
                let v = x.row(i)[j] as f64 - mu[j] as f64;
                let bit = (row.bits[j / 64] >> (j % 64)) & 1;
                assert_eq!(bit == 1, v >= 0.0, "d={d} row {i} dim {j}");
            }
            // Bits above the dimension are zero (the estimator XORs
            // whole words, so a set tail bit would corrupt Hamming
            // counts).
            if d % 64 != 0 {
                let tail = row.bits[codes.words() - 1] >> (d % 64);
                assert_eq!(tail, 0, "d={d} row {i}: tail bits set");
            }
            // Header decomposition: err² + sum_abs²/d == norm2 (exact in
            // the reals; f32 storage rounds each term).
            let h = row.head;
            if d == 0 {
                assert_eq!(
                    (h.norm2, h.sum_abs, h.scale, h.err),
                    (0.0, 0.0, 0.0, 0.0),
                    "row {i}"
                );
            } else {
                let norm2 = h.norm2 as f64;
                let lhs = (h.err as f64).powi(2) + (h.sum_abs as f64).powi(2) / d as f64;
                assert!(
                    (lhs - norm2).abs() <= 1e-4 * (1.0 + norm2),
                    "d={d} row {i}: {lhs} vs {norm2}"
                );
                let scale = h.sum_abs as f64 / d as f64;
                assert!(
                    (h.scale as f64 - scale).abs() <= 1e-5 * (1.0 + scale.abs()),
                    "d={d} row {i}"
                );
            }
        }
        // Serialize → from_parts round-trips every field bitwise.
        let back = QuantizedCodes::from_parts(
            d,
            codes.mu().to_vec(),
            &codes.heads_flat(),
            codes.bits().to_vec(),
        )
        .unwrap();
        assert_eq!(back, codes, "d={d}");
    });
}

#[test]
fn prop_quant_bounds_bracket_exact_sqdist_on_every_pair() {
    check("quant bounds bracket", 40, |rng| {
        let d = quant_dim(rng);
        let n = small_usize(rng, 1, 15);
        let m = small_usize(rng, 1, 15);
        let mut a = random_data(rng, n, d);
        let mut b = random_data(rng, m, d);
        maybe_sharpen(rng, &mut a);
        maybe_sharpen(rng, &mut b);
        // One shared μ, as in production (codes are only ever compared
        // within one centering).
        let mu = quant::column_means(&a);
        let ca = QuantizedCodes::pack(&a, &mu);
        let cb = QuantizedCodes::pack(&b, &mu);
        for i in 0..n {
            for j in 0..m {
                let exact = ops::sqdist_raw(a.row(i), b.row(j)) as f64;
                let (lb, ub) = quant::estimate_bounds(ca.row_q(i), cb.row_q(j), d);
                assert!(lb >= 0.0, "d={d} ({i},{j}): negative lb {lb}");
                assert!(
                    lb <= exact && exact <= ub,
                    "d={d} ({i},{j}): {exact} outside [{lb}, {ub}]"
                );
            }
        }
    });
}

#[test]
fn prop_quant_prune_never_drops_the_true_argmin() {
    check("quant prune keeps argmin", 30, |rng| {
        let d = quant_dim(rng);
        let k = small_usize(rng, 1, 40);
        let nq = small_usize(rng, 1, 12);
        let mut cands = random_data(rng, k, d);
        let mut queries = random_data(rng, nq, d);
        maybe_sharpen(rng, &mut cands);
        maybe_sharpen(rng, &mut queries);
        let mu = quant::column_means(&cands);
        let codes = QuantizedCodes::pack(&cands, &mu);
        let mut bits = Vec::new();
        for i in 0..nq {
            let q = queries.row(i);
            let head = quant::pack_row(q, &mu, &mut bits);
            let qp = QuantPair { query: QuantRow { head, bits: &bits }, cands: &codes };
            // Squared-domain scan: index AND value bitwise equal Strict.
            let mut cq = OpCounter::default();
            let got = NumericsMode::Quantized.nearest_sq_rows_q(q, &cands, Some(&qp), &mut cq);
            let mut cs = OpCounter::default();
            let want = NumericsMode::Strict.nearest_sq_rows(q, &cands, &mut cs);
            assert_eq!(got.0, want.0, "d={d} k={k} query {i}: argmin moved");
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "d={d} k={k} query {i}");
            assert!(cq.distances <= cs.distances, "d={d} k={k} query {i}: bill grew");
            assert_eq!(cq.estimates, k as u64, "d={d} k={k} query {i}");
            // Plain-distance scan: the sqrt at the end must not let a
            // pruned near-tie sneak back in.
            let mut cq2 = OpCounter::default();
            let got2 = NumericsMode::Quantized.nearest_rows_q(q, &cands, Some(&qp), &mut cq2);
            let want2 = NumericsMode::Strict.nearest_rows(q, &cands, &mut OpCounter::default());
            assert_eq!(got2.0, want2.0, "d={d} k={k} query {i}: plain argmin moved");
            assert_eq!(got2.1.to_bits(), want2.1.to_bits(), "d={d} k={k} query {i}");
        }
    });
}

// -------------------------------------------------------------------------
// Batched (gather-then-tile) scans: the ScanMode::Batched driver against
// the sequential bound-gated loop it replaces, across candidate counts
// that cross every TILE remainder.
// -------------------------------------------------------------------------

/// State of one synthetic bound-gated scan: the evolving best distance
/// plus one cached lower bound per candidate — the same shape every
/// trainer's inner loop threads through [`k2m::core::kernels::tile_scan_gated`].
struct GateState {
    best: f32,
    lb: Vec<f32>,
}

#[test]
fn prop_batched_scan_filter_superset_and_extras_bounded() {
    use k2m::core::kernels::{tile_scan_gated, TILE};
    check("batched scan superset + extras", 60, |rng| {
        // Candidate counts sweep 0..=3*TILE so every tile remainder
        // (and the empty scan) occurs; d small keeps distances cheap.
        let nc = small_usize(rng, 0, 3 * TILE + 1);
        let d = small_usize(rng, 1, 8);
        let rows = random_data(rng, nc.max(1), d);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        // Random cached bounds: some admit (0), some prune (huge), some
        // sit where an evolving best may overtake them mid-scan.
        let lb0: Vec<f32> = (0..nc)
            .map(|_| match small_usize(rng, 0, 3) {
                0 => 0.0,
                1 => f32::INFINITY,
                _ => rng.gaussian_f32().abs() * 2.0,
            })
            .collect();
        let ids: Vec<u32> = (0..nc as u32).collect();
        let nm = NumericsMode::Strict;

        // Sequential gated reference, recording its evaluated set.
        let mut cg = OpCounter::default();
        let mut gated = GateState { best: 4.0, lb: lb0.clone() };
        let mut evaluated = vec![false; nc];
        for t in 0..nc {
            if gated.best <= gated.lb[t] {
                continue;
            }
            evaluated[t] = true;
            let dist = nm.dist_one(&q, rows.row(t), &mut cg);
            gated.lb[t] = dist;
            if dist < gated.best {
                gated.best = dist;
            }
        }

        // Batched twin: phase-1 filter under the *initial* state, then
        // the gather-then-tile driver with the same gate replayed.
        let mut cb = OpCounter::default();
        let mut st = GateState { best: 4.0, lb: lb0.clone() };
        let mut tags: Vec<u32> = Vec::new();
        let mut sids: Vec<u32> = Vec::new();
        for t in 0..nc {
            if st.best > st.lb[t] {
                tags.push(t as u32);
                sids.push(ids[t]);
            }
        }
        // The phase-1 filter admits every candidate the gated loop
        // evaluated: its threshold is the scan-entry best, which only
        // tightens as the sequential loop advances.
        for t in 0..nc {
            if evaluated[t] {
                assert!(
                    tags.contains(&(t as u32)),
                    "nc={nc} d={d}: gated evaluated {t} but phase-1 dropped it"
                );
            }
        }
        tile_scan_gated(
            nm,
            &q,
            &rows,
            &tags,
            &sids,
            &mut st,
            &mut cb,
            |s, t| s.best > s.lb[t as usize],
            |s, t, dist| {
                let t = t as usize;
                s.lb[t] = dist;
                if dist < s.best {
                    s.best = dist;
                }
            },
        );

        // Bitwise-identical scan results…
        assert_eq!(st.best.to_bits(), gated.best.to_bits(), "nc={nc} d={d}");
        for t in 0..nc {
            assert_eq!(st.lb[t].to_bits(), gated.lb[t].to_bits(), "nc={nc} d={d} lb[{t}]");
        }
        // …with the billed overshoot bounded per scan and the gated
        // bill exactly reconstructible.
        assert!(cb.batch_extra <= (TILE - 1) as u64, "nc={nc}: {} extras", cb.batch_extra);
        assert_eq!(cb.distances, cg.distances + cb.batch_extra, "nc={nc} d={d}");
        assert_eq!(cg.batch_extra, 0);
    });
}

#[test]
fn prop_kmeanspp_labels_consistent() {
    check("++ labels point to nearest", 25, |rng| {
        let n = small_usize(rng, 5, 120);
        let d = small_usize(rng, 1, 10);
        let k = small_usize(rng, 1, n.min(10));
        let x = random_data(rng, n, d);
        let mut c = OpCounter::default();
        let init = kmeans_pp(&x, k, &mut c, rng.next_u64());
        let labels = init.labels.unwrap();
        for i in 0..n {
            let mine = ops::sqdist_raw(x.row(i), init.centers.row(labels[i] as usize));
            for j in 0..k {
                let other = ops::sqdist_raw(x.row(i), init.centers.row(j));
                assert!(mine <= other + 1e-3 * (1.0 + other), "point {i}");
            }
        }
    });
}

#[test]
fn prop_chunked_store_reads_bitwise_across_boundaries() {
    // The out-of-core store contract: any (chunk size, cache size)
    // produces the same bits as the in-RAM matrix — rows straddling
    // chunk boundaries, single-chunk caches under eviction pressure,
    // chunk sizes of 1, non-divisors, exact divisors, and > n.
    check("chunked reads bitwise", 20, |rng| {
        let n = small_usize(rng, 2, 120);
        let d = small_usize(rng, 1, 12);
        let x = random_data(rng, n, d);
        let ds = k2m::data::Dataset { name: "prop".into(), x: x.clone(), seed: 0 };
        let mut path = std::env::temp_dir();
        path.push(format!("k2m_prop_store_{}_{}.k2c", std::process::id(), rng.next_u64()));
        k2m::data::save_chunked(&ds, small_usize(rng, 1, n + 4), &path).unwrap();

        for chunk_rows in [1, small_usize(rng, 1, n + 4), n, n + 3] {
            let cache = small_usize(rng, 1, 5);
            let cm = k2m::data::ChunkedMatrix::open_with(
                &path,
                k2m::data::store::OpenOptions {
                    chunk_rows: Some(chunk_rows),
                    cache_chunks: Some(cache),
                },
            )
            .unwrap();
            // Rows around every chunk boundary, plus a shuffled gather.
            for b in (0..n).step_by(chunk_rows.max(1)) {
                for i in [b.saturating_sub(1), b, (b + 1).min(n - 1)] {
                    assert_eq!(cm.row(i), x.row(i), "row {i} chunk_rows={chunk_rows}");
                }
            }
            let idx = rng.sample_distinct(n, n.min(small_usize(rng, 1, n + 1)));
            assert_eq!(
                cm.gather_rows(&idx).as_slice(),
                Matrix::gather(&x, &idx).as_slice(),
                "gather chunk_rows={chunk_rows} cache={cache}"
            );
            assert_eq!(cm.materialize().as_slice(), x.as_slice(), "materialize");
        }
        std::fs::remove_file(&path).ok();
    });
}
