//! The batched-scan contract (`Config::scan`, `K2M_SCAN`), end to end:
//!
//! 1. **Bitwise equivalence** — [`ScanMode::Batched`] produces
//!    labels/centers/energies/iteration counts/center graphs
//!    bit-identical to [`ScanMode::Gated`] across the whole 4-init ×
//!    7-algorithm roster, on every numerics tier, at 1/4/7 threads.
//! 2. **The bill is reconstructible** — the batched tiles may evaluate
//!    at most `TILE − 1` candidates per scan that the sequential loop
//!    would have skipped; those land on `OpCounter::batch_extra` (and
//!    `distances`), so `batched.distances − batched.batch_extra ≤
//!    gated.distances` on every fixture, while the gated path never
//!    bills an extra.
//! 3. **Quantized pruning works in-loop** — on a sign-structured
//!    fixture the 1-bit estimator prunes phase-1 survivors before the
//!    tiles, making the batched exact-distance bill strictly smaller
//!    than the gated one with labels still bitwise (before this, the
//!    quantized tier only pruned the bootstrap pass).
//! 4. **Serving** — `ServeService` answers queries identically (labels,
//!    distances, and the whole counter) under either mode: its gates
//!    read only the per-query cache, which never goes stale mid-tile.

use k2m::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, Config, KmeansResult, MiniBatchOpts,
};
use k2m::core::{Matrix, NumericsMode, OpCounter, ScanMode};
use k2m::init::{gdi, kmeans_par, kmeans_pp, random_init, GdiOpts, InitResult, KmeansParOpts};
use k2m::knn::NeighborGraph;
use k2m::testing::{blobs, random_matrix};

type Algo = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;

const ALGOS: [(&str, Algo); 6] = [
    ("k2means", k2means as Algo),
    ("lloyd", lloyd as Algo),
    ("elkan", elkan as Algo),
    ("hamerly", hamerly as Algo),
    ("yinyang", yinyang as Algo),
    ("akm", akm as Algo),
];

const TIERS: [NumericsMode; 3] =
    [NumericsMode::Strict, NumericsMode::Fast, NumericsMode::Quantized];

fn inits(x: &Matrix, k: usize) -> Vec<(&'static str, InitResult)> {
    let mut c = OpCounter::default();
    vec![
        ("random", random_init(x, k, 5)),
        ("kmeans_pp", kmeans_pp(x, k, &mut c, 6)),
        ("kmeans_par", kmeans_par(x, k, &KmeansParOpts::default(), &mut c, 7)),
        ("gdi", gdi(x, k, &mut c, 8, &GdiOpts::default())),
    ]
}

fn run(
    algo: Algo,
    x: &Matrix,
    init: &InitResult,
    threads: usize,
    numerics: NumericsMode,
    scan: ScanMode,
) -> (KmeansResult, OpCounter) {
    let cfg = Config {
        k: init.k(),
        kn: 4,
        m: 8,
        max_iters: 12,
        threads,
        numerics,
        scan,
        record_trace: false,
        ..Default::default()
    };
    let mut c = OpCounter::default();
    let r = algo(x, init, &cfg, &mut c);
    (r, c)
}

fn assert_bitwise_equal(tag: &str, got: &KmeansResult, want: &KmeansResult) {
    assert_eq!(got.labels, want.labels, "{tag}: labels");
    assert_eq!(got.centers, want.centers, "{tag}: centers");
    assert_eq!(got.energy.to_bits(), want.energy.to_bits(), "{tag}: energy");
    assert_eq!(got.iters, want.iters, "{tag}: iters");
    assert_eq!(got.converged, want.converged, "{tag}: converged");
    assert_graph_bitwise(tag, got.model.graph(), want.model.graph());
}

fn assert_graph_bitwise(tag: &str, got: &NeighborGraph, want: &NeighborGraph) {
    assert_eq!(got.nbrs_flat(), want.nbrs_flat(), "{tag}: graph neighbours");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(got.dists_flat()), bits(want.dists_flat()), "{tag}: graph distances");
}

/// The shared bill invariant: the batched path's exact-distance bill,
/// net of the tile overshoot it logs, never exceeds the gated bill —
/// and the gated path never logs an overshoot at all.
fn assert_bill_invariant(tag: &str, batched: &OpCounter, gated: &OpCounter) {
    assert_eq!(gated.batch_extra, 0, "{tag}: gated path billed batch extras");
    assert!(
        batched.distances - batched.batch_extra <= gated.distances,
        "{tag}: net batched bill grew ({} - {} vs {})",
        batched.distances,
        batched.batch_extra,
        gated.distances
    );
    // Identical trajectories, so the non-scan ledgers agree.
    assert_eq!(batched.additions, gated.additions, "{tag}: additions");
    assert_eq!(batched.inner_products, gated.inner_products, "{tag}: inner products");
}

// -------------------------------------------------------------------------
// Mode plumbing
// -------------------------------------------------------------------------

#[test]
fn scan_mode_parse_names_and_default() {
    assert_eq!(ScanMode::parse("gated"), Some(ScanMode::Gated));
    assert_eq!(ScanMode::parse("GATED"), Some(ScanMode::Gated));
    assert_eq!(ScanMode::parse("batched"), Some(ScanMode::Batched));
    assert_eq!(ScanMode::parse("Batched"), Some(ScanMode::Batched));
    assert_eq!(ScanMode::parse("tiled"), None);
    assert_eq!(ScanMode::parse(""), None);
    assert_eq!(ScanMode::Gated.name(), "gated");
    assert_eq!(ScanMode::Batched.name(), "batched");
    // The config default rides the once-cached env resolution; with the
    // variable unset it lands on Batched.
    let want = match std::env::var("K2M_SCAN") {
        Ok(s) => ScanMode::parse(&s).unwrap_or(ScanMode::Batched),
        Err(_) => ScanMode::Batched,
    };
    assert_eq!(ScanMode::from_env(), want);
    assert_eq!(Config::default().scan, want);
}

// -------------------------------------------------------------------------
// 1+2. Roster: batched == gated bitwise, bill reconstructible
// -------------------------------------------------------------------------

#[test]
fn roster_batched_bitwise_equals_gated_on_every_tier() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    for (iname, init) in inits(&x, 12) {
        for (aname, algo) in ALGOS {
            for nm in TIERS {
                let (rg, cg) = run(algo, &x, &init, 1, nm, ScanMode::Gated);
                let (rb, cb) = run(algo, &x, &init, 1, nm, ScanMode::Batched);
                let tag = format!("{aname}/{iname}/{}", nm.name());
                assert_bitwise_equal(&tag, &rb, &rg);
                assert_bill_invariant(&tag, &cb, &cg);
            }
        }
        // MiniBatch rides its own signature; it has no bound-gated loop,
        // so the two modes are fully counter-identical.
        let opts = MiniBatchOpts { iterations: Some(20), eval_every: Some(10) };
        let run_mb = |scan: ScanMode| {
            let cfg = Config {
                k: 12,
                batch: 64,
                seed: 13,
                threads: 1,
                numerics: NumericsMode::Strict,
                scan,
                ..Default::default()
            };
            let mut c = OpCounter::default();
            let r = minibatch(&x, &init, &cfg, &opts, &mut c);
            (r, c)
        };
        let (rg, cg) = run_mb(ScanMode::Gated);
        let (rb, cb) = run_mb(ScanMode::Batched);
        let tag = format!("minibatch/{iname}");
        assert_eq!(rb.labels, rg.labels, "{tag}");
        assert_eq!(rb.centers, rg.centers, "{tag}");
        assert_eq!(rb.energy.to_bits(), rg.energy.to_bits(), "{tag}");
        assert_eq!(cb, cg, "{tag}: counters diverged");
    }
}

#[test]
fn batched_thread_invariant_at_1_4_7() {
    // Scratch buffers are per worker and the fold order is the candidate
    // order within each point, so the sharding never shows: batched runs
    // are bitwise and counter-identical at any thread count, and equal
    // to gated at the same count.
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    let init = random_init(&x, 12, 5);
    for (aname, algo) in ALGOS {
        for nm in TIERS {
            let (want, c1) = run(algo, &x, &init, 1, nm, ScanMode::Batched);
            for threads in [4usize, 7] {
                let (got, ct) = run(algo, &x, &init, threads, nm, ScanMode::Batched);
                let tag = format!("{aname}/{}/t{threads}", nm.name());
                assert_bitwise_equal(&tag, &got, &want);
                assert_eq!(ct, c1, "{tag}: counters diverged");
                let (gated, cg) = run(algo, &x, &init, threads, nm, ScanMode::Gated);
                assert_bitwise_equal(&format!("{tag}/vs-gated"), &got, &gated);
                assert_bill_invariant(&format!("{tag}/vs-gated"), &ct, &cg);
            }
        }
    }
}

// -------------------------------------------------------------------------
// 3. Quantized pruning in-loop: the exact bill strictly shrinks
// -------------------------------------------------------------------------

/// Near-binary ±1 sign patterns: the regime where the 1-bit estimator's
/// certified radius is tiny against the inter-pattern separations, so
/// phase-1 survivors actually prune (same fixture family as the serve
/// and kernels suites).
fn sign_structured(n: usize, d: usize, seed: u64) -> Matrix {
    let mut x = random_matrix(n, d, seed);
    for v in x.as_mut_slice() {
        *v = v.signum() + 1e-3 * *v;
    }
    x
}

#[test]
fn quantized_in_loop_pruning_strictly_reduces_the_exact_bill() {
    let x = sign_structured(360, 64, 41);
    let init = random_init(&x, 16, 42);
    let run_q = |algo: Algo, scan: ScanMode| {
        let cfg = Config {
            k: 16,
            kn: 6,
            max_iters: 10,
            threads: 1,
            numerics: NumericsMode::Quantized,
            scan,
            record_trace: false,
            ..Default::default()
        };
        let mut c = OpCounter::default();
        let r = algo(&x, &init, &cfg, &mut c);
        (r, c)
    };
    for (aname, algo, strictly) in [
        // Hamerly's rescan walks all k per triggered point, so the
        // top-2 estimator prune has the most to remove — pin the strict
        // reduction there; the bound-restricted scanners still satisfy
        // the ≤ invariant (their survivors may already be minimal).
        ("hamerly", hamerly as Algo, true),
        ("k2means", k2means as Algo, false),
        ("elkan", elkan as Algo, false),
        ("yinyang", yinyang as Algo, false),
    ] {
        let (rg, cg) = run_q(algo, ScanMode::Gated);
        let (rb, cb) = run_q(algo, ScanMode::Batched);
        let tag = format!("{aname}/quantized-sign");
        assert_bitwise_equal(&tag, &rb, &rg);
        assert_bill_invariant(&tag, &cb, &cg);
        // The in-loop estimator actually ran: the batched run spends
        // estimates past the bootstrap sweep the gated run stops at.
        assert!(
            cb.estimates > cg.estimates,
            "{tag}: no in-loop estimates ({} vs {})",
            cb.estimates,
            cg.estimates
        );
        if strictly {
            assert!(
                cb.distances < cg.distances,
                "{tag}: estimator pruned nothing ({} vs {})",
                cb.distances,
                cg.distances
            );
        }
    }
}

// -------------------------------------------------------------------------
// 4. Serving: identical answers and identical bill under either mode
// -------------------------------------------------------------------------

#[test]
fn serve_batched_is_answer_and_bill_identical() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    let init = random_init(&x, 12, 5);
    let cfg = Config { k: 12, kn: 4, max_iters: 12, threads: 1, ..Default::default() };
    let mut c = OpCounter::default();
    let model = k2means(&x, &init, &cfg, &mut c).model;
    let queries = random_matrix(64, 10, 99);
    for nm in TIERS {
        let answer = |scan: ScanMode| {
            let mut svc = k2m::runtime::ServeService::with_options(model.clone(), 1, nm);
            svc.set_scan(scan);
            let mut c = OpCounter::default();
            let (labels, dists) = svc.assign(&queries, &mut c);
            (labels, dists, c)
        };
        let (lg, dg, cg) = answer(ScanMode::Gated);
        let (lb, db, cb) = answer(ScanMode::Batched);
        let tag = format!("serve/{}", nm.name());
        assert_eq!(lb, lg, "{tag}: labels");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&db), bits(&dg), "{tag}: distances");
        // Serving gates read only the per-query cache, which cannot go
        // stale inside a tile: no extras, identical bill.
        assert_eq!(cb, cg, "{tag}: counters diverged");
    }
}
