//! The incremental moved-set refresh contract (`Config::refresh`,
//! `K2M_REFRESH`), end to end:
//!
//! 1. **Bitwise equivalence** — [`RefreshMode::Incremental`] produces
//!    labels/centers/energies/iteration counts bit-identical to
//!    [`RefreshMode::Full`] across the whole 4-init × 7-algorithm
//!    roster, at 1/4/7 threads.
//! 2. **The bill only shrinks** — the counted distance bill under
//!    Incremental is ≤ Full's on every fixture, with the avoided
//!    evaluations logged to `refresh_saved` so the full-refresh bill is
//!    reconstructible: `inc.distances + inc.refresh_saved ==
//!    full.distances`. On a converged-tail fixture (centers freeze
//!    before the run ends) the saving is strictly positive.
//! 3. **Drift patterns** — the [`KnnGraphCache`] layer handles the
//!    no-move / single-move / all-move extremes with the exact
//!    per-pattern bill, emitting the same graph bits as a from-scratch
//!    build, at any thread count.
//! 4. **Donation** — k²-means hands its in-loop graph to the
//!    [`ClusterModel`] on the max_iters fallthrough too (no post-hoc
//!    rebuild), in both refresh modes.

use k2m::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, Config, KmeansResult, MiniBatchOpts,
};
use k2m::core::{Matrix, NumericsMode, OpCounter, RefreshMode};
use k2m::init::{gdi, kmeans_par, kmeans_pp, random_init, GdiOpts, InitResult, KmeansParOpts};
use k2m::knn::{knn_graph, knn_graph_mode, KnnGraphCache, NeighborGraph};
use k2m::testing::{blobs, random_matrix};

type Algo = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;

const ALGOS: [(&str, Algo); 6] = [
    ("k2means", k2means as Algo),
    ("lloyd", lloyd as Algo),
    ("elkan", elkan as Algo),
    ("hamerly", hamerly as Algo),
    ("yinyang", yinyang as Algo),
    ("akm", akm as Algo),
];

fn inits(x: &Matrix, k: usize) -> Vec<(&'static str, InitResult)> {
    let mut c = OpCounter::default();
    vec![
        ("random", random_init(x, k, 5)),
        ("kmeans_pp", kmeans_pp(x, k, &mut c, 6)),
        ("kmeans_par", kmeans_par(x, k, &KmeansParOpts::default(), &mut c, 7)),
        ("gdi", gdi(x, k, &mut c, 8, &GdiOpts::default())),
    ]
}

fn run(
    algo: Algo,
    x: &Matrix,
    init: &InitResult,
    threads: usize,
    refresh: RefreshMode,
) -> (KmeansResult, OpCounter) {
    let cfg = Config {
        k: init.k(),
        kn: 4,
        m: 8,
        max_iters: 12,
        threads,
        numerics: NumericsMode::Strict,
        refresh,
        record_trace: false,
        ..Default::default()
    };
    let mut c = OpCounter::default();
    let r = algo(x, init, &cfg, &mut c);
    (r, c)
}

fn assert_bitwise_equal(tag: &str, got: &KmeansResult, want: &KmeansResult) {
    assert_eq!(got.labels, want.labels, "{tag}: labels");
    assert_eq!(got.centers, want.centers, "{tag}: centers");
    assert_eq!(got.energy.to_bits(), want.energy.to_bits(), "{tag}: energy");
    assert_eq!(got.iters, want.iters, "{tag}: iters");
    assert_eq!(got.converged, want.converged, "{tag}: converged");
}

fn assert_graph_bitwise(tag: &str, got: &NeighborGraph, want: &NeighborGraph) {
    assert_eq!(got.nbrs_flat(), want.nbrs_flat(), "{tag}: graph neighbours");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(got.dists_flat()), bits(want.dists_flat()), "{tag}: graph distances");
}

// -------------------------------------------------------------------------
// Mode plumbing
// -------------------------------------------------------------------------

#[test]
fn refresh_mode_parse_names_and_default() {
    assert_eq!(RefreshMode::parse("full"), Some(RefreshMode::Full));
    assert_eq!(RefreshMode::parse("FULL"), Some(RefreshMode::Full));
    assert_eq!(RefreshMode::parse("incremental"), Some(RefreshMode::Incremental));
    assert_eq!(RefreshMode::parse("Incremental"), Some(RefreshMode::Incremental));
    assert_eq!(RefreshMode::parse("partial"), None);
    assert_eq!(RefreshMode::parse(""), None);
    assert_eq!(RefreshMode::Full.name(), "full");
    assert_eq!(RefreshMode::Incremental.name(), "incremental");
    // The config default rides the once-cached env resolution; with the
    // variable unset it lands on Incremental.
    let want = match std::env::var("K2M_REFRESH") {
        Ok(s) => RefreshMode::parse(&s).unwrap_or(RefreshMode::Incremental),
        Err(_) => RefreshMode::Incremental,
    };
    assert_eq!(RefreshMode::from_env(), want);
    assert_eq!(Config::default().refresh, want);
}

// -------------------------------------------------------------------------
// 1+2. Roster: incremental == full bitwise, bill reconstructible
// -------------------------------------------------------------------------

#[test]
fn roster_incremental_bitwise_equals_full_with_reconstructible_bill() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    for (iname, init) in inits(&x, 12) {
        for (aname, algo) in ALGOS {
            let (rf, cf) = run(algo, &x, &init, 1, RefreshMode::Full);
            let (ri, ci) = run(algo, &x, &init, 1, RefreshMode::Incremental);
            let tag = format!("{aname}/{iname}");
            assert_bitwise_equal(&tag, &ri, &rf);
            // Full mode never skips work…
            assert_eq!(cf.refresh_saved, 0, "{tag}: full mode logged savings");
            // …and the incremental bill plus what it skipped *is* the
            // full bill — the honest-accounting invariant.
            assert!(ci.distances <= cf.distances, "{tag}: bill grew");
            assert_eq!(
                ci.distances + ci.refresh_saved,
                cf.distances,
                "{tag}: saved evaluations unaccounted"
            );
            // Identical trajectories, so the rest of the bill agrees.
            assert_eq!(ci.inner_products, cf.inner_products, "{tag}: inner products");
            assert_eq!(ci.additions, cf.additions, "{tag}: additions");
        }
        // MiniBatch rides its own signature. Strict is pinned (not left
        // to K2M_NUMERICS): with no center codes to refresh the modes
        // are fully bill-identical, whereas on the quantized tier Full
        // repacks k codes per refresh and Incremental repacks |M| — the
        // counter-equality assert below would be wrong there (that
        // ordering is pinned in the quantized test further down).
        let opts = MiniBatchOpts { iterations: Some(20), eval_every: Some(10) };
        let run_mb = |refresh: RefreshMode| {
            let cfg = Config {
                k: 12,
                batch: 64,
                seed: 13,
                threads: 1,
                numerics: NumericsMode::Strict,
                refresh,
                ..Default::default()
            };
            let mut c = OpCounter::default();
            let r = minibatch(&x, &init, &cfg, &opts, &mut c);
            (r, c)
        };
        let (rf, cf) = run_mb(RefreshMode::Full);
        let (ri, ci) = run_mb(RefreshMode::Incremental);
        let tag = format!("minibatch/{iname}");
        assert_eq!(ri.labels, rf.labels, "{tag}");
        assert_eq!(ri.centers, rf.centers, "{tag}");
        assert_eq!(ri.energy.to_bits(), rf.energy.to_bits(), "{tag}");
        assert_eq!(ci, cf, "{tag}: counters diverged");
    }
}

#[test]
fn incremental_thread_invariant_at_1_4_7() {
    // The moved set is a deterministic function of the center matrices,
    // which are thread-invariant — so the incremental bill (and every
    // other counter, refresh_saved included) must be too.
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    let init = random_init(&x, 12, 5);
    for (aname, algo) in ALGOS {
        let (want, c1) = run(algo, &x, &init, 1, RefreshMode::Incremental);
        for threads in [4usize, 7] {
            let (got, ct) = run(algo, &x, &init, threads, RefreshMode::Incremental);
            let tag = format!("{aname}/t{threads}");
            assert_bitwise_equal(&tag, &got, &want);
            assert_eq!(ct, c1, "{tag}: counters diverged");
        }
    }
}

#[test]
fn quantized_tier_incremental_repacks_fewer_codes_same_bits() {
    let (x, _) = blobs(420, 10, 12, 8.0, 96);
    let init = random_init(&x, 12, 97);
    for (aname, algo) in [("lloyd", lloyd as Algo), ("k2means", k2means as Algo)] {
        let run_q = |refresh: RefreshMode| {
            let cfg = Config {
                k: 12,
                kn: 4,
                max_iters: 12,
                threads: 1,
                numerics: NumericsMode::Quantized,
                refresh,
                record_trace: false,
                ..Default::default()
            };
            let mut c = OpCounter::default();
            let r = algo(&x, &init, &cfg, &mut c);
            (r, c)
        };
        let (rf, cf) = run_q(RefreshMode::Full);
        let (ri, ci) = run_q(RefreshMode::Incremental);
        assert_bitwise_equal(&format!("{aname}/quantized"), &ri, &rf);
        // μ is frozen per run, so an unmoved center's code is bitwise
        // reusable and only moved rows repack: never more than Full's
        // k-per-refresh, and the counted distance bill never grows.
        assert!(ci.packs <= cf.packs, "{aname}: pack bill grew");
        assert!(ci.distances <= cf.distances, "{aname}: distance bill grew");
        assert_eq!(ci.distances + ci.refresh_saved, cf.distances, "{aname}: bill leak");
    }
}

// -------------------------------------------------------------------------
// 2b. Converged tail: the saving is strictly positive (acceptance pin)
// -------------------------------------------------------------------------

/// A fixture with a guaranteed converged tail: well-separated blobs plus
/// an init that duplicates two of its rows. Ties in the argmin go to the
/// lower index, so each duplicate owns zero points from the first
/// assignment on; the empty-cluster convention keeps its row bitwise
/// forever — at least two centers are "frozen" in every update step, so
/// every per-iteration refresh from iteration 2 on has unmoved pairs to
/// reuse.
fn converged_tail_fixture() -> (Matrix, InitResult) {
    let (x, _) = blobs(360, 8, 10, 25.0, 71);
    let mut centers = random_init(&x, 12, 72).centers;
    let dup0: Vec<f32> = centers.row(0).to_vec();
    let dup1: Vec<f32> = centers.row(1).to_vec();
    centers.row_mut(10).copy_from_slice(&dup0);
    centers.row_mut(11).copy_from_slice(&dup1);
    (x, InitResult { centers, labels: None })
}

#[test]
fn converged_tail_saves_strictly() {
    let (x, init) = converged_tail_fixture();
    for (aname, algo) in
        [("elkan", elkan as Algo), ("hamerly", hamerly as Algo), ("k2means", k2means as Algo)]
    {
        let (rf, cf) = run(algo, &x, &init, 1, RefreshMode::Full);
        let (ri, ci) = run(algo, &x, &init, 1, RefreshMode::Incremental);
        let tag = format!("{aname}/tail");
        assert_bitwise_equal(&tag, &ri, &rf);
        assert!(ri.iters >= 2, "{tag}: fixture too easy to exercise a refresh");
        assert!(ci.refresh_saved > 0, "{tag}: no refresh ever saved work");
        assert!(
            ci.distances < cf.distances,
            "{tag}: frozen centers saved nothing ({} vs {})",
            ci.distances,
            cf.distances
        );
        assert_eq!(ci.distances + ci.refresh_saved, cf.distances, "{tag}: bill leak");
    }
}

// -------------------------------------------------------------------------
// 3. Drift patterns at the KnnGraphCache layer
// -------------------------------------------------------------------------

#[test]
fn graph_cache_drift_patterns_no_move_single_move_all_move() {
    let k = 17;
    let kn = 5;
    let nm = NumericsMode::Strict;
    let centers = random_matrix(k, 9, 61);
    let pairs = (k * (k - 1) / 2) as u64;
    let pattern = |label: &str, moved: Vec<bool>| {
        let m = moved.iter().filter(|&&b| b).count();
        let unmoved_pairs = ((k - m) * (k - m).saturating_sub(1) / 2) as u64;
        // Mutate the chosen rows so the moved set is honest.
        let mut after = centers.clone();
        for (j, &mv) in moved.iter().enumerate() {
            if mv {
                for v in after.row_mut(j) {
                    *v += 0.25;
                }
            }
        }
        for threads in [1usize, 4, 7] {
            let mut c = OpCounter::default();
            let mut cache =
                KnnGraphCache::new(&centers, kn, &mut c, threads, nm, RefreshMode::Incremental);
            let mut cu = OpCounter::default();
            cache.update(&after, Some(&moved), &mut cu, threads, nm);
            // Exact per-pattern bill: the pairs among unmoved centers —
            // and only those — are reused.
            let tag = format!("{label}/t{threads}");
            assert_eq!(cu.distances, pairs - unmoved_pairs, "{tag}: bill");
            assert_eq!(cu.refresh_saved, unmoved_pairs, "{tag}: saved");
            // Same graph bits as building from scratch on the new rows.
            let mut cw = OpCounter::default();
            let want = knn_graph_mode(&after, kn, &mut cw, 1, nm);
            assert_graph_bitwise(&tag, cache.graph(), &want);
        }
    };
    pattern("no-move", vec![false; k]);
    let mut single = vec![false; k];
    single[9] = true;
    pattern("single-move", single);
    pattern("all-move", vec![true; k]);
}

// -------------------------------------------------------------------------
// 4. k²-means donates its graph on the max_iters fallthrough
// -------------------------------------------------------------------------

#[test]
fn k2means_max_iters_fallthrough_donates_fresh_graph_in_both_modes() {
    let (x, _) = blobs(420, 10, 12, 6.0, 83);
    let mut c0 = OpCounter::default();
    let init = gdi(&x, 12, &mut c0, 84, &GdiOpts::default());
    let mut models = Vec::new();
    for refresh in [RefreshMode::Full, RefreshMode::Incremental] {
        // A cap low enough that the run cannot converge: the fallthrough
        // arm, where the seed behaviour rebuilt the graph post hoc.
        // Strict is pinned (not left to K2M_NUMERICS): the reference
        // build below is the Strict-pinned `knn_graph`, and on the fast
        // tier the donated graph's distance bits legitimately differ.
        let cfg = Config {
            k: 12,
            kn: 4,
            max_iters: 2,
            threads: 1,
            numerics: NumericsMode::Strict,
            refresh,
            record_trace: false,
            ..Default::default()
        };
        let mut c = OpCounter::default();
        let r = k2means(&x, &init, &cfg, &mut c);
        assert!(!r.converged, "{}: fixture converged under the cap", refresh.name());
        // The donated graph matches a from-scratch build over the final
        // centers, bit for bit — the model never serves a stale graph.
        let mut cg = OpCounter::default();
        let want = knn_graph(&r.centers, 4, &mut cg);
        assert_graph_bitwise(&format!("donation/{}", refresh.name()), r.model.graph(), &want);
        models.push(r);
    }
    // And the two modes donated the same graph.
    assert_graph_bitwise(
        "donation/full-vs-incremental",
        models[1].model.graph(),
        models[0].model.graph(),
    );
    assert_bitwise_equal("donation/full-vs-incremental", &models[1], &models[0]);
}
