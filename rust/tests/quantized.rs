//! Contract suite for the Quantized numerics tier (`core::kernels`,
//! third rung): the prune/re-rank layer must be **invisible in the
//! answers** and visible only in the bills.
//!
//! Four rungs, mirroring `tests/numerics.rs`'s structure for the fast
//! tier:
//!
//! 1. **Roster parity** — the all-inits × all-algorithms roster run end
//!    to end on Strict and on Quantized: labels, centers, and energies
//!    **bitwise equal** (not merely close — the pruned scans re-rank
//!    survivors with the strict kernels and a pruned candidate is
//!    *certified* to lose), the exact-distance bill ≤ Strict's, and the
//!    estimator/pack work billed on its own counters which Strict never
//!    touches.
//! 2. **Determinism** — bit-identical results and counters (including
//!    estimates/packs) at 1 vs 4 vs 7 threads, and bitwise run-to-run
//!    stability on the reused process-wide pool.
//! 3. **The tier actually prunes** — on sign-structured (near-binary)
//!    data the exact bill drops strictly below Strict's while the
//!    answers stay bitwise equal; on isotropic gaussian fixtures the
//!    certified radius exceeds the separations, nothing is pruned, and
//!    the bills coincide exactly — both regimes are pinned.
//! 4. **Train → save → serve** — a Quantized-trained model round-trips
//!    through the `.k2mm` v2 codes section and serves bit-identically
//!    to the in-memory model.

use k2m::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, ClusterModel, Config, KmeansResult,
    MiniBatchOpts,
};
use k2m::core::{Matrix, NumericsMode, OpCounter, RefreshMode};
use k2m::init::{
    gdi, kmeans_par, kmeans_pp_numerics, random_init, GdiOpts, InitResult, KmeansParOpts,
};
use k2m::runtime::ServeService;
use k2m::testing::{blobs, random_matrix};

type Algo = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;

const ALGOS: [(&str, Algo); 6] = [
    ("k2means", k2means as Algo),
    ("lloyd", lloyd as Algo),
    ("elkan", elkan as Algo),
    ("hamerly", hamerly as Algo),
    ("yinyang", yinyang as Algo),
    ("akm", akm as Algo),
];

/// The four init families, each built **on the given tier** (serial) so
/// a mode's roster is end-to-end in that mode, with the init's own op
/// bill returned for the parity checks.
fn inits(x: &Matrix, k: usize, nm: NumericsMode) -> Vec<(&'static str, InitResult, OpCounter)> {
    let mut out = Vec::new();
    out.push(("random", random_init(x, k, 5), OpCounter::default()));
    let mut c = OpCounter::default();
    let pp = kmeans_pp_numerics(x, k, &mut c, 6, 1, nm);
    out.push(("kmeans_pp", pp, c));
    let mut c = OpCounter::default();
    let par = kmeans_par(
        x,
        k,
        &KmeansParOpts { threads: 1, numerics: nm, ..Default::default() },
        &mut c,
        7,
    );
    out.push(("kmeans_par", par, c));
    let mut c = OpCounter::default();
    let g = gdi(x, k, &mut c, 8, &GdiOpts { threads: 1, numerics: nm, ..Default::default() });
    out.push(("gdi", g, c));
    out
}

fn run(
    algo: Algo,
    x: &Matrix,
    init: &InitResult,
    threads: usize,
    nm: NumericsMode,
) -> (KmeansResult, OpCounter) {
    let cfg = Config {
        k: init.k(),
        kn: 4,
        m: 8,
        max_iters: 12,
        threads,
        numerics: nm,
        record_trace: false,
        ..Default::default()
    };
    let mut c = OpCounter::default();
    let r = algo(x, init, &cfg, &mut c);
    (r, c)
}

/// Sign-structured data: `k` near-binary ±1 patterns plus `1e-4`
/// jitter, point `i` riding pattern `i % k`. The regime the quantized
/// estimator was built for — codes carry almost all of the signal, so
/// the certified bounds separate and pruning fires.
fn sign_blobs(n: usize, k: usize, d: usize, seed: u64) -> Matrix {
    let pat = random_matrix(k, d, seed);
    let jit = random_matrix(n, d, seed + 1);
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        for (j, xv) in x.row_mut(i).iter_mut().enumerate() {
            *xv = pat.row(i % k)[j].signum() + 1e-4 * jit.row(i)[j];
        }
    }
    x
}

fn assert_bitwise_equal(tag: &str, a: &KmeansResult, b: &KmeansResult) {
    assert_eq!(a.labels, b.labels, "{tag}: labels");
    assert_eq!(a.centers, b.centers, "{tag}: centers");
    assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{tag}: energy");
    assert_eq!(a.iters, b.iters, "{tag}: iters");
}

// -------------------------------------------------------------------------
// 1. Roster parity: Quantized answers are Strict answers, bit for bit
// -------------------------------------------------------------------------

#[test]
fn roster_quantized_vs_strict_bitwise_with_smaller_or_equal_exact_bill() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    let strict_inits = inits(&x, 12, NumericsMode::Strict);
    let quant_inits = inits(&x, 12, NumericsMode::Quantized);
    for ((iname, si, sc), (_, qi, qc)) in strict_inits.iter().zip(&quant_inits) {
        // Inits route through the dispatch arms (no candidate scans to
        // prune), so the init phase is bitwise identical across tiers.
        assert_eq!(si.centers, qi.centers, "{iname} init centers");
        assert_eq!(sc.distances, qc.distances, "{iname} init distances");
        for (aname, algo) in ALGOS {
            let (rs, cs) = run(algo, &x, si, 1, NumericsMode::Strict);
            let (rq, cq) = run(algo, &x, qi, 1, NumericsMode::Quantized);
            let tag = format!("{aname}/{iname}");
            assert_bitwise_equal(&tag, &rq, &rs);
            // Exact work can only shrink; estimator work rides separate
            // counters that the strict tier never touches.
            assert!(
                cq.distances <= cs.distances,
                "{tag}: quantized exact bill {} > strict {}",
                cq.distances,
                cs.distances
            );
            assert_eq!(cq.inner_products, cs.inner_products, "{tag}: inner products");
            assert_eq!(cq.additions, cs.additions, "{tag}: additions");
            assert_eq!((cs.estimates, cs.packs), (0, 0), "{tag}: strict billed estimator work");
            // On these isotropic gaussian blobs the certified radius
            // exceeds the inter-center separations, so nothing can be
            // pruned and the bills coincide *exactly* — the regime
            // where the tier can't win, pinned.
            assert_eq!(cq.distances, cs.distances, "{tag}: gaussian prune fired unexpectedly");
        }
    }
}

#[test]
fn minibatch_quantized_parity_and_thread_invariance() {
    let (x, _) = blobs(900, 12, 10, 8.0, 92);
    let init = random_init(&x, 12, 93);
    let opts = MiniBatchOpts { iterations: Some(30), eval_every: Some(10) };
    let run_mb = |threads: usize, nm: NumericsMode| {
        let cfg = Config {
            k: 12,
            batch: 300,
            seed: 13,
            threads,
            numerics: nm,
            // Pinned Full so the packs bill below stays the analytic
            // k-per-iteration constant; the incremental moved-row
            // repack (packs = |M| per iteration) is pinned separately
            // in tests/refresh.rs.
            refresh: RefreshMode::Full,
            ..Default::default()
        };
        let mut c = OpCounter::default();
        let r = minibatch(&x, &init, &cfg, &opts, &mut c);
        (r, c)
    };
    let (rs, cs) = run_mb(1, NumericsMode::Strict);
    let (rq, cq) = run_mb(1, NumericsMode::Quantized);
    assert_bitwise_equal("minibatch", &rq, &rs);
    assert!(cq.distances <= cs.distances);
    // Centers drift every iteration, so the codes re-pack each round on
    // top of the initial point+center packing.
    assert_eq!(cq.packs as usize, 900 + 12 + 30 * 12);
    for threads in [4usize, 7] {
        let (got, ct) = run_mb(threads, NumericsMode::Quantized);
        assert_bitwise_equal(&format!("minibatch/t{threads}"), &got, &rq);
        assert_eq!(ct, cq, "t{threads}: counters diverged");
    }
}

#[test]
fn k2means_ablation_quantized_matches_strict_bitwise() {
    // use_bounds: false is the paper's ablation arm — a plain blocked
    // candidate scan every iteration, which is exactly the shape the
    // quantized tier prunes. The answers must not move.
    let (x, _) = blobs(420, 10, 12, 8.0, 96);
    let init = random_init(&x, 12, 97);
    let run_ab = |nm: NumericsMode| {
        let cfg = Config {
            k: 12,
            kn: 4,
            m: 8,
            max_iters: 12,
            use_bounds: false,
            numerics: nm,
            record_trace: false,
            ..Default::default()
        };
        let mut c = OpCounter::default();
        let r = k2means(&x, &init, &cfg, &mut c);
        (r, c)
    };
    let (rs, cs) = run_ab(NumericsMode::Strict);
    let (rq, cq) = run_ab(NumericsMode::Quantized);
    assert_bitwise_equal("k2means/ablation", &rq, &rs);
    assert!(cq.distances <= cs.distances);
    assert!(cq.estimates > 0, "ablation scans never estimated");
    assert_eq!((cs.estimates, cs.packs), (0, 0));
}

// -------------------------------------------------------------------------
// 2. Determinism: threads and run-to-run
// -------------------------------------------------------------------------

#[test]
fn roster_quantized_bit_identical_at_1_4_7_threads() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    for (iname, init, _) in inits(&x, 12, NumericsMode::Quantized) {
        for (aname, algo) in ALGOS {
            let (want, c1) = run(algo, &x, &init, 1, NumericsMode::Quantized);
            for threads in [4usize, 7] {
                let (got, ct) = run(algo, &x, &init, threads, NumericsMode::Quantized);
                let tag = format!("{aname}/{iname}/t{threads}");
                assert_bitwise_equal(&tag, &got, &want);
                // The whole counter — estimates and packs included —
                // is thread-invariant (shard merges are ordered).
                assert_eq!(ct, c1, "{tag}: counters diverged");
            }
        }
    }
}

#[test]
fn quantized_run_to_run_bitwise_stable_on_reused_pool() {
    let (x, _) = blobs(420, 10, 12, 8.0, 91);
    let init = gdi(
        &x,
        12,
        &mut OpCounter::default(),
        9,
        &GdiOpts { threads: 1, numerics: NumericsMode::Quantized, ..Default::default() },
    );
    let sweep = || {
        ALGOS
            .iter()
            .map(|&(_, algo)| run(algo, &x, &init, 4, NumericsMode::Quantized))
            .collect::<Vec<_>>()
    };
    let a = sweep();
    let b = sweep();
    for (((ra, ca), (rb, cb)), (name, _)) in a.iter().zip(&b).zip(ALGOS.iter()) {
        assert_bitwise_equal(name, ra, rb);
        assert_eq!(ca, cb, "{name}: counters diverged run to run");
    }
}

// -------------------------------------------------------------------------
// 3. The tier actually prunes where it should
// -------------------------------------------------------------------------

#[test]
fn lloyd_on_sign_structured_data_prunes_without_moving_a_bit() {
    let x = sign_blobs(400, 10, 64, 41);
    let init = random_init(&x, 10, 42);
    let run_l = |nm: NumericsMode| {
        let cfg = Config { k: 10, max_iters: 10, numerics: nm, ..Default::default() };
        let mut c = OpCounter::default();
        let r = lloyd(&x, &init, &cfg, &mut c);
        (r, c)
    };
    let (rs, cs) = run_l(NumericsMode::Strict);
    let (rq, cq) = run_l(NumericsMode::Quantized);
    assert_bitwise_equal("lloyd/sign", &rq, &rs);
    assert!(cq.estimates > 0);
    assert!(cq.packs > 0);
    assert!(
        cq.distances < cs.distances,
        "pruning never fired on sign-structured data: {} vs {}",
        cq.distances,
        cs.distances
    );
    // The bills that aren't about candidate scans are untouched.
    assert_eq!(cq.additions, cs.additions);
}

// -------------------------------------------------------------------------
// 4. Train → save → serve on the quantized tier
// -------------------------------------------------------------------------

#[test]
fn quantized_model_save_load_serve_is_bit_identical() {
    let centers = random_matrix(24, 16, 51);
    let cfg = Config { k: 24, kn: 5, numerics: NumericsMode::Quantized, ..Default::default() };
    let model = ClusterModel::build(centers, &cfg);
    assert!(model.has_codes(), "quantized training must materialize codes");

    let mut p = std::env::temp_dir();
    p.push(format!("k2m_test_{}_quantized_serve.k2mm", std::process::id()));
    model.save(&p).unwrap();
    let loaded = ClusterModel::load(&p).unwrap();
    std::fs::remove_file(&p).ok();
    assert!(loaded.has_codes(), "codes section must travel in the file");
    assert_eq!(loaded.quant_codes(), model.quant_codes());

    let q = random_matrix(150, 16, 52);
    let svc_mem = ServeService::with_options(model, 1, NumericsMode::Quantized);
    let svc_disk = ServeService::with_options(loaded, 1, NumericsMode::Quantized);
    let (mut cm, mut cd) = (OpCounter::default(), OpCounter::default());
    let (lm, dm) = svc_mem.assign(&q, &mut cm);
    let (ld, dd) = svc_disk.assign(&q, &mut cd);
    assert_eq!(lm, ld);
    for (a, b) in dm.iter().zip(&dd) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(cm, cd, "serve bills diverged across the round-trip");
    // Top-m through the same round-trip.
    let (im, tm) = svc_mem.nearest_centers(&q, 6, &mut OpCounter::default());
    let (id, td) = svc_disk.nearest_centers(&q, 6, &mut OpCounter::default());
    assert_eq!(im, id);
    for (a, b) in tm.iter().zip(&td) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
