//! Offline **stub** of the `xla` crate's PJRT API surface.
//!
//! The real XLA backend (`src/runtime/xla_engine.rs`, behind the
//! `xla-pjrt` cargo feature) targets the external `xla` crate: PJRT C
//! API bindings over the `xla_extension` native library. That crate
//! cannot live in the offline vendor set, but the backend's *code*
//! should still be type-checked — otherwise the feature-gated module
//! rots silently. This stub mirrors exactly the types and signatures
//! the backend uses, so `cargo check --features xla-pjrt` compiles the
//! real implementation end to end (CI's xla-check job). At runtime
//! [`PjRtClient::cpu`] fails with instructions: swap this path
//! dependency for the real `xla` crate to actually execute on PJRT.
//!
//! Every constructor that could yield a live handle returns [`Err`], so
//! the remaining methods are unreachable in practice — they exist to
//! satisfy the signatures (honest errors rather than `unreachable!`, so
//! an accidental use stays debuggable).

use std::path::Path;

const STUB: &str = "offline xla stub: replace rust/vendor/xla with the real `xla` crate \
     (PJRT bindings + the xla_extension native library) to execute on PJRT";

/// Stub error; callers format it with `{:?}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn stub_err<T>() -> Result<T, Error> {
    Err(Error(STUB.to_string()))
}

/// Element types a [`Literal`] can hold or yield.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 literal over a native-typed slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        stub_err()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        stub_err()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_err()
    }
}

/// Parsed HLO module (the AOT artifacts are HLO text).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        stub_err()
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err()
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub — see the crate docs.
    pub fn cpu() -> Result<PjRtClient, Error> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_fail_honestly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.clone().reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
