//! Minimal, dependency-free subset of the `anyhow` error-handling API,
//! vendored in-tree so the crate builds with zero network access (the
//! offline vendor set ships no external registry crates).
//!
//! Covered surface — exactly what this repository uses:
//!
//! * [`Error`]: an opaque error value built from a message or any
//!   `std::error::Error`, carrying its source chain as text.
//! * [`Result<T>`]: alias with `Error` as the default error type.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, wrapping the inner error with an outer message.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Formatting matches the upstream conventions the callers rely on:
//! `{}` prints the outermost message only, `{:#}` prints the whole chain
//! joined by `": "` (what `eprintln!("error: {e:#}")` expects).
//!
//! Like upstream, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion to coexist with the reflexive
//! `From<Error> for Error` used by `?`.

use std::fmt;

/// Opaque error: an outermost message plus the flattened source chain.
pub struct Error {
    /// Outermost context first; deepest cause last. Never empty.
    chain: Vec<String>,
}

impl Error {
    /// Create from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend an outer context message (the `.context(..)` operation).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Adds `.context(..)` / `.with_context(..)` to `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with an outer message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Like [`Context::context`], with the message built lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing thing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("open {}", "x.bin")).unwrap_err();
        assert_eq!(format!("{e:#}"), "open x.bin: missing thing");
    }

    #[test]
    fn macros_compose() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        fn failing() -> Result<u32> {
            bail!("always fails with code {}", 3);
        }
        assert_eq!(inner(true).unwrap(), 1);
        assert_eq!(format!("{}", inner(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", failing().unwrap_err()), "always fails with code 3");
        let e = anyhow!("direct {}", 5);
        assert_eq!(format!("{e}"), "direct 5");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e: Error = Err::<(), _>(io_err()).context("ctx").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx") && dbg.contains("missing thing"), "{dbg}");
    }
}
