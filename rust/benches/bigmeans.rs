//! Big-means benchmark: samples/sec of the decomposition driver over
//! in-RAM vs chunked (out-of-core) sources, plus the energy-vs-wall
//! trajectory against one full-data k²-means run — the perf story of
//! the out-of-core tentpole: how fast sample subproblems drive the
//! incumbent down before a full-data pass would even finish.
//!
//! `cargo bench --bench bigmeans`. Sized to stay CI-friendly (the
//! bench-smoke job runs it and uploads the `K2M_BENCH_JSON` artifact).

use std::time::Instant;

use k2m::bench::Harness;
use k2m::cluster::{bigmeans, k2means, BigMeansOpts, Config};
use k2m::core::OpCounter;
use k2m::data::store::OpenOptions;
use k2m::data::{save_chunked, ChunkedMatrix, Dataset, DatasetSource};
use k2m::init::{gdi, GdiOpts};
use k2m::testing::blobs;

const N: usize = 24_000;
const D: usize = 16;
const K: usize = 64;
const SAMPLE_ROWS: usize = 2_000;
const SAMPLES: usize = 8;

fn cfg() -> Config {
    Config { k: K, kn: 16, max_iters: 10, seed: 7, record_trace: false, ..Config::default() }
}

fn driver_opts() -> BigMeansOpts {
    BigMeansOpts { samples: SAMPLES, sample_rows: SAMPLE_ROWS, round: 4, ..Default::default() }
}

fn bench_driver(h: &Harness, label: &str, shape: &str, src: &DatasetSource) {
    let cfg = cfg();
    let opts = driver_opts();
    let s = h.run_tagged(&format!("bigmeans [{label}]"), shape, "k2means", || {
        bigmeans(src, &cfg, &opts, &mut OpCounter::default())
    });
    println!(
        "    -> {:.1} samples/s ({} samples x {} rows, assign pass included)",
        s.throughput(SAMPLES as f64),
        SAMPLES,
        SAMPLE_ROWS
    );
}

fn main() {
    let (x, _) = blobs(N, K, D, 12.0, 5);
    let h = Harness { min_iters: 3, max_iters: 15, ..Default::default() };

    println!("== big-means driver (n={N} d={D} k={K}) ==");
    let ram = DatasetSource::from(x.clone());
    bench_driver(&h, "in-RAM", "ram", &ram);

    // The same schedule over the chunked store at two cache pressures:
    // the gap to in-RAM is pure IO + decode.
    let mut path = std::env::temp_dir();
    path.push(format!("k2m_bench_bigmeans_{}.k2c", std::process::id()));
    let ds = Dataset { name: "bench".into(), x: x.clone(), seed: 5 };
    save_chunked(&ds, 2_048, &path).unwrap();
    for cache in [2usize, 16] {
        let cm = ChunkedMatrix::open_with(
            &path,
            OpenOptions { chunk_rows: None, cache_chunks: Some(cache) },
        )
        .unwrap();
        let src = DatasetSource::from(cm);
        bench_driver(&h, &format!("chunked/cache={cache}"), &format!("k2c:{cache}"), &src);
    }
    std::fs::remove_file(&path).ok();

    // Energy-vs-wall trajectory: the incumbent after each sample vs one
    // full-data k²-means run — single timed passes (the trajectory is
    // the artifact, not the median).
    println!("\n== energy vs wall: big-means trajectory vs full-data k2means ==");
    let cfg = cfg();
    let t0 = Instant::now();
    let out = bigmeans(&ram, &cfg, &driver_opts(), &mut OpCounter::default());
    let big_wall = t0.elapsed();
    for p in &out.result.trace.points {
        println!("    sample {:>2}: energy {:.6e} at {:.3e} ops", p.iter, p.energy, p.ops);
    }
    println!("    big-means total: {:?} (full_energy {:.6e})", big_wall, out.result.energy);

    let mut counter = OpCounter::default();
    let t1 = Instant::now();
    let gopts = GdiOpts::default();
    let init = gdi(&x, K, &mut counter, cfg.seed, &gopts);
    let full = k2means(&x, &init, &cfg, &mut counter);
    println!(
        "    full-data k2means: {:?} (energy {:.6e}, {} iters, {:.3e} ops)",
        t1.elapsed(),
        full.energy,
        full.iters,
        counter.total()
    );
}
