//! Wallclock benchmarks of the L3 hot-path primitives (the §Perf targets
//! of EXPERIMENTS.md): squared distance, dot product, and the batched
//! assignment inner loop at the paper's representative dimensions.
//!
//! `cargo bench --bench kernels`

use k2m::bench::Harness;
use k2m::core::{ops, Matrix};
use k2m::rng::Pcg32;

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::seeded(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32();
        }
    }
    m
}

fn main() {
    let h = Harness::default();
    println!("== kernels: counted-op primitives ==");

    // sqdist at the paper's d values.
    for d in [50usize, 256, 784, 3072] {
        let a = random_matrix(2, d, 1);
        let (x, y) = (a.row(0).to_vec(), a.row(1).to_vec());
        let stats = h.run(&format!("sqdist d={d} (x1e4)"), || {
            let mut acc = 0.0f32;
            for _ in 0..10_000 {
                acc += ops::sqdist_raw(std::hint::black_box(&x), std::hint::black_box(&y));
            }
            acc
        });
        let flops = 3.0 * d as f64 * 10_000.0;
        println!(
            "    -> {:.2} GFLOP/s",
            flops / stats.median.as_secs_f64() / 1e9
        );
    }

    for d in [50usize, 784] {
        let a = random_matrix(2, d, 2);
        let (x, y) = (a.row(0).to_vec(), a.row(1).to_vec());
        h.run(&format!("dot d={d} (x1e4)"), || {
            let mut acc = 0.0f32;
            for _ in 0..10_000 {
                acc += ops::dot_raw(std::hint::black_box(&x), std::hint::black_box(&y));
            }
            acc
        });
    }

    // Full assignment pass: n x k at mnist50-like and cnnvoc-like shapes.
    println!("\n== kernels: assignment inner loop ==");
    for (n, k, d) in [(2000usize, 200usize, 50usize), (500, 100, 1024)] {
        let x = random_matrix(n, d, 3);
        let c = random_matrix(k, d, 4);
        let stats = h.run(&format!("assign n={n} k={k} d={d}"), || {
            let mut labels = vec![0u32; n];
            for i in 0..n {
                let xi = x.row(i);
                let mut best = (0u32, f32::INFINITY);
                for j in 0..k {
                    let dist = ops::sqdist_raw(xi, c.row(j));
                    if dist < best.1 {
                        best = (j as u32, dist);
                    }
                }
                labels[i] = best.0;
            }
            labels
        });
        let flops = 3.0 * (n * k * d) as f64;
        println!(
            "    -> {:.2} GFLOP/s  ({:.1} Mdist/s)",
            flops / stats.median.as_secs_f64() / 1e9,
            (n * k) as f64 / stats.median.as_secs_f64() / 1e6
        );
    }
}
