//! Wallclock benchmarks of the L3 hot-path primitives (the §Perf targets
//! of EXPERIMENTS.md): squared distance, dot product, the batched
//! assignment inner loop at the paper's representative dimensions, the
//! **scalar-vs-blocked** comparison for the `core::kernels` layer, the
//! **strict-vs-fast** numerics-tier comparison, and the
//! **strict-vs-quantized** prune/re-rank scan on sign-structured data
//! (EXPERIMENTS.md §Perf — the comparison sections print ready-to-paste
//! markdown rows).
//!
//! `cargo bench --bench kernels`

use k2m::bench::Harness;
use k2m::core::kernels::fast;
use k2m::core::kernels::quant::{self, QuantPair, QuantRow, QuantizedCodes};
use k2m::core::{kernels, ops, Matrix, NumericsMode, OpCounter};
use k2m::rng::Pcg32;

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::seeded(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32();
        }
    }
    m
}

fn main() {
    let h = Harness::default();
    println!("== kernels: counted-op primitives ==");

    // sqdist at the paper's d values.
    for d in [50usize, 256, 784, 3072] {
        let a = random_matrix(2, d, 1);
        let (x, y) = (a.row(0).to_vec(), a.row(1).to_vec());
        let stats = h.run(&format!("sqdist d={d} (x1e4)"), || {
            let mut acc = 0.0f32;
            for _ in 0..10_000 {
                acc += ops::sqdist_raw(std::hint::black_box(&x), std::hint::black_box(&y));
            }
            acc
        });
        let flops = 3.0 * d as f64 * 10_000.0;
        println!(
            "    -> {:.2} GFLOP/s",
            flops / stats.median.as_secs_f64() / 1e9
        );
    }

    for d in [50usize, 784] {
        let a = random_matrix(2, d, 2);
        let (x, y) = (a.row(0).to_vec(), a.row(1).to_vec());
        h.run(&format!("dot d={d} (x1e4)"), || {
            let mut acc = 0.0f32;
            for _ in 0..10_000 {
                acc += ops::dot_raw(std::hint::black_box(&x), std::hint::black_box(&y));
            }
            acc
        });
    }

    // Full assignment pass: n x k at mnist50-like and cnnvoc-like shapes.
    println!("\n== kernels: assignment inner loop ==");
    for (n, k, d) in [(2000usize, 200usize, 50usize), (500, 100, 1024)] {
        let x = random_matrix(n, d, 3);
        let c = random_matrix(k, d, 4);
        let stats = h.run(&format!("assign n={n} k={k} d={d}"), || {
            let mut labels = vec![0u32; n];
            for i in 0..n {
                let xi = x.row(i);
                let mut best = (0u32, f32::INFINITY);
                for j in 0..k {
                    let dist = ops::sqdist_raw(xi, c.row(j));
                    if dist < best.1 {
                        best = (j as u32, dist);
                    }
                }
                labels[i] = best.0;
            }
            labels
        });
        let flops = 3.0 * (n * k * d) as f64;
        println!(
            "    -> {:.2} GFLOP/s  ({:.1} Mdist/s)",
            flops / stats.median.as_secs_f64() / 1e9,
            (n * k) as f64 / stats.median.as_secs_f64() / 1e6
        );
    }

    // Scalar vs blocked: the core::kernels comparison. One query row
    // against a candidate list — the k²-means kn-scan shape — across
    // (d, candidate-count) pairs, then the full short-pass assignment
    // shape n=2000, k=256 (EXPERIMENTS.md §Perf protocol; the rows
    // below paste straight into the markdown table).
    println!("\n== kernels: scalar vs blocked candidate scans ==");
    println!("| scan | d | cands | scalar median | blocked median | speedup |");
    println!("|---|---|---|---|---|---|");
    for (d, nc) in [(50usize, 30usize), (50, 200), (256, 30), (784, 100), (3072, 30)] {
        let rows = random_matrix(nc, d, 5);
        let q = random_matrix(1, d, 6);
        let cand: Vec<u32> = (0..nc as u32).collect();
        let mut out = vec![0.0f32; nc];
        // One optimization barrier per kernel call in BOTH arms (a
        // per-candidate barrier would deny the scalar loop the
        // keep-the-query-row-hot optimization the comparison measures).
        let scalar = h.run(&format!("scalar scan d={d} nc={nc} (x256)"), || {
            let mut acc = 0.0f32;
            for _ in 0..256 {
                let qr = std::hint::black_box(q.row(0));
                for (t, &j) in cand.iter().enumerate() {
                    out[t] = ops::sqdist_raw(qr, rows.row(j as usize));
                }
                acc += out[nc - 1];
            }
            acc
        });
        let blocked = h.run(&format!("blocked scan d={d} nc={nc} (x256)"), || {
            let mut acc = 0.0f32;
            for _ in 0..256 {
                let qr = std::hint::black_box(q.row(0));
                kernels::sqdist_block_raw(qr, &rows, &cand, &mut out);
                acc += out[nc - 1];
            }
            acc
        });
        println!(
            "| sqdist | {d} | {nc} | {:?} | {:?} | {:.2}x |",
            scalar.median,
            blocked.median,
            scalar.median.as_secs_f64() / blocked.median.as_secs_f64()
        );
    }
    // The short-pass shape (n=2000, k=256): per-pass wall clock where
    // dispatch and locality, not raw FLOPs, set the budget.
    {
        let (n, k, d) = (2000usize, 256usize, 32usize);
        let x = random_matrix(n, d, 7);
        let c = random_matrix(k, d, 8);
        let scalar = h.run("assign scalar n=2000 k=256 d=32", || {
            let mut labels = vec![0u32; n];
            for i in 0..n {
                let xi = x.row(i);
                let mut best = (0u32, f32::INFINITY);
                for j in 0..k {
                    let dist = ops::sqdist_raw(xi, c.row(j));
                    if dist < best.1 {
                        best = (j as u32, dist);
                    }
                }
                labels[i] = best.0;
            }
            labels
        });
        let blocked = h.run("assign blocked n=2000 k=256 d=32", || {
            let mut labels = vec![0u32; n];
            for (i, lab) in labels.iter_mut().enumerate() {
                let (best, _) = kernels::nearest_sq_rows_raw(x.row(i), &c);
                *lab = best;
            }
            labels
        });
        println!(
            "| assign n=2000 k=256 | {d} | {k} | {:?} | {:?} | {:.2}x |",
            scalar.median,
            blocked.median,
            scalar.median.as_secs_f64() / blocked.median.as_secs_f64()
        );
    }

    // Strict vs fast numerics tiers: the same blocked candidate scan on
    // the bit-pinned strict kernels vs the lane-striped fast tier, at
    // the paper's benchmark dims (SIFT=128, GIST=960, d=64…2048 shapes
    // of EXPERIMENTS.md "Strict vs fast numerics"). Same memory walk,
    // different accumulation structure — the speedup is pure summation
    // ILP.
    println!("\n== kernels: strict vs fast numerics tiers ==");
    println!("| scan | d | cands | strict median | fast median | speedup |");
    println!("|---|---|---|---|---|---|");
    for (d, nc) in [(64usize, 30usize), (128, 100), (256, 30), (960, 100), (2048, 30)] {
        let rows = random_matrix(nc, d, 9);
        let q = random_matrix(1, d, 10);
        let cand: Vec<u32> = (0..nc as u32).collect();
        let mut out = vec![0.0f32; nc];
        let strict = h.run(&format!("strict scan d={d} nc={nc} (x256)"), || {
            let mut acc = 0.0f32;
            for _ in 0..256 {
                let qr = std::hint::black_box(q.row(0));
                kernels::sqdist_block_raw(qr, &rows, &cand, &mut out);
                acc += out[nc - 1];
            }
            acc
        });
        let fast_s = h.run(&format!("fast scan d={d} nc={nc} (x256)"), || {
            let mut acc = 0.0f32;
            for _ in 0..256 {
                let qr = std::hint::black_box(q.row(0));
                fast::sqdist_block_raw(qr, &rows, &cand, &mut out);
                acc += out[nc - 1];
            }
            acc
        });
        println!(
            "| sqdist | {d} | {nc} | {:?} | {:?} | {:.2}x |",
            strict.median,
            fast_s.median,
            strict.median.as_secs_f64() / fast_s.median.as_secs_f64()
        );
    }
    // The short-pass assignment shape again, this time tier vs tier.
    {
        let (n, k, d) = (2000usize, 256usize, 32usize);
        let x = random_matrix(n, d, 11);
        let c = random_matrix(k, d, 12);
        let strict = h.run("assign strict n=2000 k=256 d=32", || {
            let mut labels = vec![0u32; n];
            for (i, lab) in labels.iter_mut().enumerate() {
                let (best, _) = kernels::nearest_sq_rows_raw(x.row(i), &c);
                *lab = best;
            }
            labels
        });
        let fast_s = h.run("assign fast n=2000 k=256 d=32", || {
            let mut labels = vec![0u32; n];
            for (i, lab) in labels.iter_mut().enumerate() {
                let (best, _) = fast::nearest_sq_rows_raw(x.row(i), &c);
                *lab = best;
            }
            labels
        });
        println!(
            "| assign n=2000 k=256 | {d} | {k} | {:?} | {:?} | {:.2}x |",
            strict.median,
            fast_s.median,
            strict.median.as_secs_f64() / fast_s.median.as_secs_f64()
        );
    }

    // Gated vs batched candidate scans: the sequential bound-gated loop
    // (check the cached bound, evaluate, fold — one candidate at a
    // time) vs the same scan as filter → gather → tile through
    // `tile_scan_gated` (EXPERIMENTS.md "Gated vs batched scans",
    // kernel-level rows). The survivor fraction sweeps the regimes:
    // everything survives (pure tiling win), half survives (mixed), and
    // a late-iteration 10% (gather overhead vs a short scalar walk).
    // Both arms produce bitwise-identical `best`; only the loop shape
    // and the ≤ TILE−1 `batch_extra` overshoot differ.
    println!("\n== kernels: gated vs batched (gather-then-tile) scans ==");
    println!("| d | cands | survive | gated median | batched median | extras | speedup |");
    println!("|---|---|---|---|---|---|---|");
    struct ScanState {
        best: f32,
        lb: Vec<f32>,
    }
    for (d, nc) in [(50usize, 30usize), (128, 100), (784, 100)] {
        for survive_pct in [100usize, 50, 10] {
            let rows = random_matrix(nc, d, 41 + d as u64);
            let q = random_matrix(1, d, 42);
            // Cached bounds admitting roughly `survive_pct` of the
            // candidates; the rest carry an infinite lower bound and
            // never evaluate in either arm.
            let mut rng = Pcg32::seeded(43 + survive_pct as u64);
            let lb0: Vec<f32> = (0..nc)
                .map(|_| if rng.gen_below(100) < survive_pct { 0.0 } else { f32::INFINITY })
                .collect();
            let ids: Vec<u32> = (0..nc as u32).collect();
            let nm = NumericsMode::Strict;
            let run_gated = || {
                let mut ctr = OpCounter::default();
                let qr = std::hint::black_box(q.row(0));
                let mut st = ScanState { best: f32::INFINITY, lb: lb0.clone() };
                for (t, &j) in ids.iter().enumerate() {
                    if st.best <= st.lb[t] {
                        continue;
                    }
                    let dist = nm.dist_one(qr, rows.row(j as usize), &mut ctr);
                    st.lb[t] = dist;
                    if dist < st.best {
                        st.best = dist;
                    }
                }
                st.best
            };
            let run_batched = || {
                let mut ctr = OpCounter::default();
                let qr = std::hint::black_box(q.row(0));
                let mut st = ScanState { best: f32::INFINITY, lb: lb0.clone() };
                // Phase 1: filter on the cached bounds under the
                // initial state (zero evaluations), gathering survivor
                // handles; phase 2: tile-evaluate with the same gate
                // replayed under the evolving state.
                let mut tags: Vec<u32> = Vec::with_capacity(nc);
                let mut sids: Vec<u32> = Vec::with_capacity(nc);
                for (t, &j) in ids.iter().enumerate() {
                    if st.best > st.lb[t] {
                        tags.push(t as u32);
                        sids.push(j);
                    }
                }
                kernels::tile_scan_gated(
                    nm,
                    qr,
                    &rows,
                    &tags,
                    &sids,
                    &mut st,
                    &mut ctr,
                    |s, t| s.best > s.lb[t as usize],
                    |s, t, dist| {
                        let t = t as usize;
                        s.lb[t] = dist;
                        if dist < s.best {
                            s.best = dist;
                        }
                    },
                );
                (st.best, ctr.batch_extra)
            };
            // The overshoot bill, reported once (it is deterministic).
            let extras = run_batched().1;
            let shape = format!("d={d} nc={nc} sv={survive_pct}%");
            let gated = h.run_tagged(
                &format!("gated scan {shape} (x256)"),
                &shape,
                "gated",
                || {
                    let mut acc = 0.0f32;
                    for _ in 0..256 {
                        acc += run_gated();
                    }
                    acc
                },
            );
            let batched = h.run_tagged(
                &format!("batched scan {shape} (x256)"),
                &shape,
                "batched",
                || {
                    let mut acc = 0.0f32;
                    for _ in 0..256 {
                        acc += run_batched().0;
                    }
                    acc
                },
            );
            println!(
                "| {d} | {nc} | {survive_pct}% | {:?} | {:?} | {extras} | {:.2}x |",
                gated.median,
                batched.median,
                gated.median.as_secs_f64() / batched.median.as_secs_f64()
            );
        }
    }

    // Strict full scan vs quantized estimate → prune → strict-re-rank,
    // in both prune regimes (EXPERIMENTS.md "Quantized vs strict/fast").
    // `sign` rows are near-binary ±1 patterns — the certified radius is
    // tiny against the inter-pattern separations, so almost every
    // candidate prunes and the exact re-rank touches a handful of rows.
    // `gauss` rows are isotropic — the radius swallows the separations,
    // the lower bounds clamp to 0, nothing prunes, and the tier pays
    // the estimator sweep ON TOP of the full strict scan: the honest
    // fall-through cost. The survivors column is the exact-distance
    // bill out of `nc` candidates (labels are bitwise strict either
    // way — that contract is pinned in tests/quantized.rs, not here).
    println!("\n== kernels: strict full scan vs quantized prune/re-rank ==");
    println!("| data | d | cands | strict median | quantized median | survivors | speedup |");
    println!("|---|---|---|---|---|---|---|");
    for (d, nc) in [(64usize, 30usize), (128, 100), (256, 30), (960, 100), (2048, 30)] {
        for sign_structured in [true, false] {
            let mut rows = random_matrix(nc, d, 13 + d as u64);
            if sign_structured {
                for i in 0..nc {
                    for v in rows.row_mut(i) {
                        *v = v.signum() + 1e-4 * *v;
                    }
                }
            }
            // The query rides candidate 0's pattern, nudged off the
            // exact point so the scan still has real work to do.
            let mut q: Vec<f32> = rows.row(0).to_vec();
            for v in &mut q {
                *v += 1e-3;
            }
            let mu = quant::column_means(&rows);
            let codes = QuantizedCodes::pack(&rows, &mu);
            let mut qbits = Vec::new();
            let head = quant::pack_row(&q, &mu, &mut qbits);
            let tag = if sign_structured { "sign" } else { "gauss" };
            let strict = h.run(&format!("strict scan [{tag}] d={d} nc={nc} (x256)"), || {
                let mut acc = 0u32;
                for _ in 0..256 {
                    let (best, _) = kernels::nearest_sq_rows_raw(std::hint::black_box(&q), &rows);
                    acc += best;
                }
                acc
            });
            let quant_s = h.run(&format!("quant scan [{tag}] d={d} nc={nc} (x256)"), || {
                let mut acc = 0u32;
                for _ in 0..256 {
                    let mut ctr = OpCounter::default();
                    let qp = QuantPair { query: QuantRow { head, bits: &qbits }, cands: &codes };
                    let (best, _) = NumericsMode::Quantized.nearest_sq_rows_q(
                        std::hint::black_box(&q),
                        &rows,
                        Some(&qp),
                        &mut ctr,
                    );
                    acc += best;
                }
                acc
            });
            let mut ctr = OpCounter::default();
            let qp = QuantPair { query: QuantRow { head, bits: &qbits }, cands: &codes };
            let _ = NumericsMode::Quantized.nearest_sq_rows_q(&q, &rows, Some(&qp), &mut ctr);
            println!(
                "| {tag} | {d} | {nc} | {:?} | {:?} | {}/{nc} | {:.2}x |",
                strict.median,
                quant_s.median,
                ctr.distances,
                strict.median.as_secs_f64() / quant_s.median.as_secs_f64()
            );
        }
    }

    // The estimator's innermost primitive: XOR + popcount over the code
    // words. Before = the single-accumulator fold the estimator shipped
    // with; after = the 4-way unrolled `quant::xor_popcount` it calls
    // now (bit-identical sum — integer addition is associative — so the
    // swap is pure ILP). Word counts are the paper dims' code widths
    // (`words = ceil(d/64)`: d=64, 256, 784, 3072).
    println!("\n== kernels: quantized XOR+popcount (fold vs unrolled) ==");
    println!("| words | fold median | unrolled median | speedup |");
    println!("|---|---|---|---|");
    for words in [1usize, 4, 13, 48] {
        let mut rng = Pcg32::seeded(97 + words as u64);
        let xw: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let yw: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let fold = h.run(&format!("popcount fold w={words} (x1e5)"), || {
            let mut acc = 0u64;
            for _ in 0..100_000 {
                let (x, y) = (std::hint::black_box(&xw), std::hint::black_box(&yw));
                acc += x
                    .iter()
                    .zip(y.iter())
                    .fold(0u64, |acc, (&a, &b)| acc + (a ^ b).count_ones() as u64);
            }
            acc
        });
        let unrolled = h.run(&format!("popcount unrolled w={words} (x1e5)"), || {
            let mut acc = 0u64;
            for _ in 0..100_000 {
                acc += quant::xor_popcount(
                    std::hint::black_box(&xw),
                    std::hint::black_box(&yw),
                );
            }
            acc
        });
        println!(
            "| {words} | {:?} | {:?} | {:.2}x |",
            fold.median,
            unrolled.median,
            fold.median.as_secs_f64() / unrolled.median.as_secs_f64()
        );
    }
}
