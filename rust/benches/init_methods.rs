//! Initialization benchmarks (paper Tables 4/7 in wallclock form):
//! random vs k-means++ vs GDI across k, on a fixed dataset.
//!
//! `cargo bench --bench init_methods`

use k2m::bench::Harness;
use k2m::core::OpCounter;
use k2m::coordinator::inits::InitMethod;
use k2m::data;

fn main() {
    let h = Harness { min_iters: 3, max_iters: 20, ..Default::default() };
    let ds = data::usps_like(0.3, 0xD5); // n≈2187, d=256
    println!("== initializations on {} n={} d={} ==", ds.name, ds.n(), ds.d());

    for k in [50usize, 200, 500] {
        println!("\n-- k = {k} --");
        for method in InitMethod::ALL {
            let mut ops = 0.0;
            let stats = h.run(&format!("{} k={k}", method.name()), || {
                let mut counter = OpCounter::default();
                let init = method.run(&ds.x, k, 0, &mut counter);
                ops = counter.total();
                init
            });
            println!(
                "    -> {:.3e} vector ops, {:?} median",
                ops, stats.median
            );
        }
    }
    println!("\n(expect GDI wallclock & ops to scale ~log k vs ++'s ~k — paper Table 3)");
}
