//! Engine-path benchmarks: the native backend vs the PJRT/AOT backend on
//! the batched steps — the three-layer architecture's throughput story.
//! XLA benches skip (loudly) when `make artifacts` hasn't run.
//!
//! `cargo bench --bench engine`

use k2m::bench::Harness;
use k2m::core::Matrix;
use k2m::rng::Pcg32;
use k2m::runtime::{default_artifact_dir, Engine, RustEngine, XlaEngine};

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::seeded(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32();
        }
    }
    m
}

fn bench_engine(h: &Harness, name: &str, engine: &mut dyn Engine) {
    let (n, k, kn, d) = (4096usize, 256usize, 32usize, 64usize);
    let x = random_matrix(n, d, 1);
    let c = random_matrix(k, d, 2);
    let mut rng = Pcg32::seeded(3);
    let cand: Vec<u32> = (0..n * kn).map(|_| rng.gen_below(k) as u32).collect();
    let labels: Vec<u32> = (0..n).map(|_| rng.gen_below(k) as u32).collect();

    let s = h.run(&format!("{name}: assign_full n={n} k={k} d={d}"), || {
        engine.assign_full(&x, &c).unwrap()
    });
    println!("    -> {:.2} Mpoints/s", n as f64 / s.median.as_secs_f64() / 1e6);

    let s = h.run(&format!("{name}: assign_candidates kn={kn}"), || {
        engine.assign_candidates(&x, &c, &cand, kn).unwrap()
    });
    println!("    -> {:.2} Mpoints/s", n as f64 / s.median.as_secs_f64() / 1e6);

    h.run(&format!("{name}: center_knn k={k} kn={kn}"), || {
        engine.center_knn(&c, kn).unwrap()
    });

    h.run(&format!("{name}: update_stats"), || {
        engine.update_stats(&x, &labels, k).unwrap()
    });
}

fn main() {
    let h = Harness { min_iters: 3, max_iters: 15, ..Default::default() };

    println!("== native engine ==");
    let mut native = RustEngine;
    bench_engine(&h, "rust", &mut native);

    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        println!("\nSKIP xla engine: artifacts missing — run `make artifacts`");
        return;
    }
    println!("\n== xla-pjrt engine (AOT JAX+Pallas artifacts) ==");
    match XlaEngine::new(&dir) {
        Ok(mut xla) => bench_engine(&h, "xla", &mut xla),
        Err(e) => println!("SKIP xla engine: {e:#}"),
    }
}
