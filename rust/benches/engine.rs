//! Engine-path benchmarks: the sharded parallel execution engine across
//! every algorithm it powers (1→N thread scaling curves over (n, d, k,
//! kn) shapes — the §Perf protocol of EXPERIMENTS.md, emitted as
//! markdown-ready table rows), then the native backend vs the PJRT/AOT
//! backend on the batched steps — the three-layer architecture's
//! throughput story. XLA benches skip (loudly) when `make artifacts`
//! hasn't run.
//!
//! `cargo bench --bench engine`

use k2m::bench::Harness;
use k2m::cluster::{
    elkan, hamerly, k2means, lloyd, minibatch, update_means_threaded, yinyang, Config,
    KmeansResult, MiniBatchOpts,
};
use k2m::core::{Matrix, NumericsMode, OpCounter, RefreshMode, ScanMode};
use k2m::init::{gdi, random_init, GdiOpts, InitResult};
use k2m::knn::KnnGraphCache;
use k2m::rng::Pcg32;
use k2m::runtime::{default_artifact_dir, Engine, RustEngine, XlaEngine};

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::seeded(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32();
        }
    }
    m
}

fn bench_engine(h: &Harness, name: &str, engine: &mut dyn Engine) {
    let (n, k, kn, d) = (4096usize, 256usize, 32usize, 64usize);
    let x = random_matrix(n, d, 1);
    let c = random_matrix(k, d, 2);
    let mut rng = Pcg32::seeded(3);
    let cand: Vec<u32> = (0..n * kn).map(|_| rng.gen_below(k) as u32).collect();
    let labels: Vec<u32> = (0..n).map(|_| rng.gen_below(k) as u32).collect();

    let s = h.run(&format!("{name}: assign_full n={n} k={k} d={d}"), || {
        engine.assign_full(&x, &c).unwrap()
    });
    println!("    -> {:.2} Mpoints/s", n as f64 / s.median.as_secs_f64() / 1e6);

    let s = h.run(&format!("{name}: assign_candidates kn={kn}"), || {
        engine.assign_candidates(&x, &c, &cand, kn).unwrap()
    });
    println!("    -> {:.2} Mpoints/s", n as f64 / s.median.as_secs_f64() / 1e6);

    h.run(&format!("{name}: center_knn k={k} kn={kn}"), || {
        engine.center_knn(&c, kn).unwrap()
    });

    h.run(&format!("{name}: update_stats"), || {
        engine.update_stats(&x, &labels, k).unwrap()
    });
}

type Algo = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;

/// The Lloyd-family roster that shares a signature; MiniBatch and GDI
/// (different signatures) are benched alongside in [`bench_scaling`].
const ALGOS: [(&str, Algo); 5] = [
    ("k2means", k2means as Algo),
    ("lloyd", lloyd as Algo),
    ("elkan", elkan as Algo),
    ("hamerly", hamerly as Algo),
    ("yinyang", yinyang as Algo),
];

/// The EXPERIMENTS.md §Perf protocol: 1→N thread scaling of every
/// sharded algorithm across (n, d, k, kn) shapes, emitted as
/// markdown-ready rows (paste them straight into the §Perf table).
/// Results are bit-identical across rows of the same (algo, shape) by
/// the engine's determinism contract — only the wall clock moves.
fn bench_scaling() {
    // Short runs (the scaling story is per-pass, not per-convergence):
    // 3 iterations per run, no trace, median of >= 2 timed samples.
    let h = Harness {
        warmup: 1,
        min_iters: 2,
        max_iters: 5,
        min_time: std::time::Duration::from_millis(200),
    };
    // (label, n, d, k, kn): the paper's mnist50 headline shape, a
    // deeper-d / smaller-n shape, and a **short-pass** shape (tiny n,
    // many clusters — iterations finish in fractions of a millisecond)
    // where per-pass dispatch overhead dominates: this is the row that
    // makes the persistent pool's win over per-pass scoped spawning
    // visible (EXPERIMENTS.md §Perf, pool-vs-scoped-spawn protocol).
    let shapes: [(&str, usize, usize, usize, usize); 3] = [
        ("mnist50", 60_000, 50, 200, 30),
        ("deep128", 10_000, 128, 128, 16),
        ("shortpass", 2_000, 32, 256, 16),
    ];

    // One §Perf table row per (algo, threads): run at each thread
    // count, hold the 1-thread median as the speedup baseline. The row
    // format is the EXPERIMENTS.md comparable-rows contract — keep the
    // two in sync.
    let emit_rows = |label: &str,
                     (n, d, k): (usize, usize, usize),
                     kn_cell: &str,
                     thread_counts: &[usize],
                     run: &mut dyn FnMut(usize) -> k2m::bench::Stats| {
        let mut serial: Option<std::time::Duration> = None;
        for &threads in thread_counts {
            let stats = run(threads);
            let ms = stats.median.as_secs_f64() * 1e3;
            let speedup = match serial {
                None => {
                    serial = Some(stats.median);
                    1.0
                }
                Some(t1) => t1.as_secs_f64() / stats.median.as_secs_f64(),
            };
            println!(
                "| {label} | {n} | {d} | {k} | {kn_cell} | {threads} | {ms:.1} | {speedup:.2}x |"
            );
        }
    };

    println!("== sharded engine: 1->N thread scaling (EXPERIMENTS.md §Perf rows) ==");
    println!("| algo | n | d | k | kn | threads | median ms | speedup |");
    println!("|---|---|---|---|---|---|---|---|");
    for &(shape, n, d, k, kn) in &shapes {
        let x = random_matrix(n, d, 7);
        let init = random_init(&x, k, 8);

        // The shared-signature roster: 3 sharded iterations each
        // (unseeded: one full bootstrap + bounded assignment passes).
        for (algo_name, algo) in ALGOS {
            let kn_cell = kn.to_string();
            emit_rows(algo_name, (n, d, k), &kn_cell, &[1, 2, 4, 8], &mut |threads| {
                let cfg = Config {
                    k,
                    kn,
                    max_iters: 3,
                    record_trace: false,
                    threads,
                    ..Default::default()
                };
                h.run(&format!("{algo_name} {shape} [{threads}t]"), || {
                    let mut counter = OpCounter::default();
                    algo(&x, &init, &cfg, &mut counter)
                })
            });
        }

        // MiniBatch: a batch large enough to shard (the paper's b=100
        // stays serial under auto — benching the engine needs width).
        let b = 8192.min(n);
        let opts = MiniBatchOpts { iterations: Some(10), eval_every: Some(100) };
        emit_rows(&format!("minibatch(b={b})"), (n, d, k), "-", &[1, 2, 4, 8], &mut |threads| {
            let cfg = Config { k, batch: b, record_trace: false, threads, ..Default::default() };
            h.run(&format!("minibatch {shape} b={b} [{threads}t]"), || {
                let mut counter = OpCounter::default();
                minibatch(&x, &init, &cfg, &opts, &mut counter)
            })
        });

        // GDI: the divisive initialization end to end (its projection
        // scans shard; the early whole-dataset splits dominate).
        emit_rows("gdi", (n, d, k), "-", &[1, 2, 4, 8], &mut |threads| {
            let gopts = GdiOpts { threads, ..Default::default() };
            h.run(&format!("gdi {shape} [{threads}t]"), || {
                let mut counter = OpCounter::default();
                gdi(&x, k, &mut counter, 9, &gopts)
            })
        });

        // The cluster-sharded update step on the same shape.
        let labels: Vec<u32> = {
            let mut rng = Pcg32::seeded(10);
            (0..n).map(|_| rng.gen_below(k) as u32).collect()
        };
        emit_rows("update_means", (n, d, k), "-", &[1, 8], &mut |threads| {
            h.run(&format!("update_means {shape} [{threads}t]"), || {
                let mut counter = OpCounter::default();
                update_means_threaded(&x, &labels, &init.centers, &mut counter, threads)
            })
        });
        println!();
    }
}

/// The EXPERIMENTS.md §Perf K2M_SHARD_MIN sweep: auto-threaded (threads
/// = 0) passes over sizes that straddle the shard floor, labeled with
/// the floor active in *this* process. The floor is read once per
/// process (`OnceLock`, like `K2M_THREADS`), so the sweep is
/// cross-process by design — re-run the whole bench under each floor:
///
/// ```text
/// for s in 256 512 1024 2048; do K2M_SHARD_MIN=$s cargo bench --bench engine; done
/// ```
///
/// and paste each run's rows into the §Perf sweep table. Auto mode
/// spends a thread only on shards holding >= floor points, so the rows
/// below the active floor stay serial (the floor's whole point: don't
/// pay dispatch where a pass is cheaper than the handoff).
fn bench_shard_min() {
    let h = Harness {
        warmup: 1,
        min_iters: 3,
        max_iters: 10,
        min_time: std::time::Duration::from_millis(100),
    };
    let floor = k2m::coordinator::pool::min_auto_chunk();
    println!("== K2M_SHARD_MIN sweep rows (active floor: {floor}) ==");
    println!("| shard_min | n | d | k | median ms |");
    println!("|---|---|---|---|---|");
    let (d, k, kn) = (32usize, 64usize, 16usize);
    for n in [1_024usize, 2_048, 4_096, 8_192, 16_384] {
        let x = random_matrix(n, d, 11);
        let init = random_init(&x, k, 12);
        // threads: 0 — auto mode is the only resolution path the floor
        // touches; explicit counts bypass it entirely.
        let cfg =
            Config { k, kn, max_iters: 3, record_trace: false, threads: 0, ..Default::default() };
        let stats = h.run(&format!("k2means auto n={n} [floor={floor}]"), || {
            let mut counter = OpCounter::default();
            k2means(&x, &init, &cfg, &mut counter)
        });
        println!("| {floor} | {n} | {d} | {k} | {:.1} |", stats.median.as_secs_f64() * 1e3);
    }
    println!();
}

/// The EXPERIMENTS.md "Incremental refresh" protocol: (a) the
/// [`KnnGraphCache`] maintenance pass alone — one full rebuild vs one
/// incremental update at a sweep of moved fractions (the late-iteration
/// regimes where the moved set shrinks), and (b) the per-run phase
/// split — the same trainer under `--refresh full` vs `incremental`,
/// where the gap is exactly the avoided center-maintenance work
/// (assignment phases are bit-identical by contract). Rows paste into
/// the EXPERIMENTS.md skeleton tables — keep the two in sync.
fn bench_refresh() {
    let h = Harness {
        warmup: 1,
        min_iters: 3,
        max_iters: 10,
        min_time: std::time::Duration::from_millis(100),
    };
    let (k, d, kn) = (256usize, 64usize, 32usize);
    let centers = random_matrix(k, d, 21);
    let nm = NumericsMode::Strict;

    println!("== incremental refresh: graph maintenance vs moved fraction ==");
    println!("| k | d | kn | moved | full rebuild | incremental update | speedup |");
    println!("|---|---|---|---|---|---|---|");
    for moved_pct in [100usize, 50, 10, 1, 0] {
        let m = (k * moved_pct).div_ceil(100).min(k);
        // Nudge the first m rows so the moved set is honest.
        let mut after = centers.clone();
        let mut moved = vec![false; k];
        for (j, mv) in moved.iter_mut().enumerate().take(m) {
            *mv = true;
            for v in after.row_mut(j) {
                *v += 0.25;
            }
        }
        let full = h.run(&format!("graph full rebuild [moved={moved_pct}%]"), || {
            let mut c = OpCounter::default();
            let mut cache = KnnGraphCache::new(&centers, kn, &mut c, 1, nm, RefreshMode::Full);
            cache.update(&after, Some(&moved), &mut c, 1, nm);
            cache
        });
        let inc = h.run(&format!("graph incremental [moved={moved_pct}%]"), || {
            let mut c = OpCounter::default();
            let mut cache =
                KnnGraphCache::new(&centers, kn, &mut c, 1, nm, RefreshMode::Incremental);
            cache.update(&after, Some(&moved), &mut c, 1, nm);
            cache
        });
        println!(
            "| {k} | {d} | {kn} | {moved_pct}% | {:?} | {:?} | {:.2}x |",
            full.median,
            inc.median,
            full.median.as_secs_f64() / inc.median.as_secs_f64()
        );
    }

    println!("\n== incremental refresh: full-run phase split (full vs incremental) ==");
    println!("| algo | n | d | k | full median ms | incremental median ms | speedup |");
    println!("|---|---|---|---|---|---|---|");
    let (n, d, k, kn) = (8192usize, 32usize, 256usize, 16usize);
    let x = random_matrix(n, d, 22);
    let init = random_init(&x, k, 23);
    let algos: [(&str, Algo); 3] =
        [("k2means", k2means as Algo), ("elkan", elkan as Algo), ("hamerly", hamerly as Algo)];
    for (name, algo) in algos {
        let run_mode = |refresh: RefreshMode| {
            let cfg = Config {
                k,
                kn,
                max_iters: 20,
                record_trace: false,
                threads: 1,
                refresh,
                ..Default::default()
            };
            h.run(&format!("{name} refresh={}", refresh.name()), || {
                let mut counter = OpCounter::default();
                algo(&x, &init, &cfg, &mut counter)
            })
        };
        let full = run_mode(RefreshMode::Full);
        let inc = run_mode(RefreshMode::Incremental);
        println!(
            "| {name} | {n} | {d} | {k} | {:.1} | {:.1} | {:.2}x |",
            full.median.as_secs_f64() * 1e3,
            inc.median.as_secs_f64() * 1e3,
            full.median.as_secs_f64() / inc.median.as_secs_f64()
        );
    }
    println!();
}

/// The EXPERIMENTS.md "Gated vs batched scans" protocol: every
/// bound-pruned trainer under `--scan gated` vs `batched`, per numerics
/// tier — the wall-clock side of the [`ScanMode`] contract (results are
/// bitwise equal by `tests/scan.rs`, so only time and the `batch_extra`
/// bill move). Rows paste into the EXPERIMENTS.md skeleton table, and
/// with `K2M_BENCH_JSON=BENCH_9.json` each cell also lands as a tagged
/// JSON row (`shape` = the workload, `mode` = `<scan>/<numerics>`).
fn bench_scan() {
    let h = Harness {
        warmup: 1,
        min_iters: 3,
        max_iters: 10,
        min_time: std::time::Duration::from_millis(100),
    };
    println!("== gated vs batched scans: trainer wall clock per numerics tier ==");
    println!("| algo | numerics | n | d | k | gated median ms | batched median ms | speedup |");
    println!("|---|---|---|---|---|---|---|---|");
    let (n, d, k, kn) = (8192usize, 32usize, 256usize, 16usize);
    let shape = format!("{n}x{d} k={k} kn={kn}");
    let x = random_matrix(n, d, 31);
    let init = random_init(&x, k, 32);
    let algos: [(&str, Algo); 4] = [
        ("k2means", k2means as Algo),
        ("elkan", elkan as Algo),
        ("hamerly", hamerly as Algo),
        ("yinyang", yinyang as Algo),
    ];
    for (name, algo) in algos {
        for nm in [NumericsMode::Strict, NumericsMode::Fast, NumericsMode::Quantized] {
            let run_mode = |scan: ScanMode| {
                let cfg = Config {
                    k,
                    kn,
                    max_iters: 20,
                    record_trace: false,
                    threads: 1,
                    numerics: nm,
                    scan,
                    ..Default::default()
                };
                h.run_tagged(
                    &format!("{name} scan={} numerics={}", scan.name(), nm.name()),
                    &shape,
                    &format!("{}/{}", scan.name(), nm.name()),
                    || {
                        let mut counter = OpCounter::default();
                        algo(&x, &init, &cfg, &mut counter)
                    },
                )
            };
            let gated = run_mode(ScanMode::Gated);
            let batched = run_mode(ScanMode::Batched);
            println!(
                "| {name} | {} | {n} | {d} | {k} | {:.1} | {:.1} | {:.2}x |",
                nm.name(),
                gated.median.as_secs_f64() * 1e3,
                batched.median.as_secs_f64() * 1e3,
                gated.median.as_secs_f64() / batched.median.as_secs_f64()
            );
        }
    }
    println!();
}

fn main() {
    bench_shard_min();
    bench_refresh();
    bench_scan();
    bench_scaling();

    let h = Harness { min_iters: 3, max_iters: 15, ..Default::default() };
    println!("== native engine (strict tier) ==");
    let mut native = RustEngine::with_numerics(k2m::core::NumericsMode::Strict);
    bench_engine(&h, "rust", &mut native);

    println!("\n== native engine (fast tier, K2M_NUMERICS=fast equivalent) ==");
    let mut native_fast = RustEngine::with_numerics(k2m::core::NumericsMode::Fast);
    bench_engine(&h, "rust-fast", &mut native_fast);

    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        println!("\nSKIP xla engine: artifacts missing — run `make artifacts`");
        return;
    }
    println!("\n== xla-pjrt engine (AOT JAX+Pallas artifacts) ==");
    match XlaEngine::new(&dir) {
        Ok(mut xla) => bench_engine(&h, "xla", &mut xla),
        Err(e) => println!("SKIP xla engine: {e:#}"),
    }
}
