//! Engine-path benchmarks: the sharded parallel execution engine on the
//! k²-means hot path (1 vs N threads on the paper's n=60k, d=50, k=200
//! workload shape), then the native backend vs the PJRT/AOT backend on
//! the batched steps — the three-layer architecture's throughput story.
//! XLA benches skip (loudly) when `make artifacts` hasn't run.
//!
//! `cargo bench --bench engine`

use k2m::bench::Harness;
use k2m::cluster::{k2means, update_means_threaded, Config};
use k2m::core::{Matrix, OpCounter};
use k2m::init::random_init;
use k2m::rng::Pcg32;
use k2m::runtime::{default_artifact_dir, Engine, RustEngine, XlaEngine};

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::seeded(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32();
        }
    }
    m
}

fn bench_engine(h: &Harness, name: &str, engine: &mut dyn Engine) {
    let (n, k, kn, d) = (4096usize, 256usize, 32usize, 64usize);
    let x = random_matrix(n, d, 1);
    let c = random_matrix(k, d, 2);
    let mut rng = Pcg32::seeded(3);
    let cand: Vec<u32> = (0..n * kn).map(|_| rng.gen_below(k) as u32).collect();
    let labels: Vec<u32> = (0..n).map(|_| rng.gen_below(k) as u32).collect();

    let s = h.run(&format!("{name}: assign_full n={n} k={k} d={d}"), || {
        engine.assign_full(&x, &c).unwrap()
    });
    println!("    -> {:.2} Mpoints/s", n as f64 / s.median.as_secs_f64() / 1e6);

    let s = h.run(&format!("{name}: assign_candidates kn={kn}"), || {
        engine.assign_candidates(&x, &c, &cand, kn).unwrap()
    });
    println!("    -> {:.2} Mpoints/s", n as f64 / s.median.as_secs_f64() / 1e6);

    h.run(&format!("{name}: center_knn k={k} kn={kn}"), || {
        engine.center_knn(&c, kn).unwrap()
    });

    h.run(&format!("{name}: update_stats"), || {
        engine.update_stats(&x, &labels, k).unwrap()
    });
}

/// The sharded-engine headline: wall-clock of the k²-means hot path on
/// the paper's mnist50 workload shape (n=60k, d=50, k=200, kn=30) at 1
/// vs N threads. Labels are bit-identical across rows by construction;
/// the 8-thread row is expected to come in >= 3x over serial on >= 8
/// hardware threads.
fn bench_sharded_engine(h: &Harness) {
    let (n, d, k, kn) = (60_000usize, 50usize, 200usize, 30usize);
    println!("== sharded engine: k2-means assignment hot path (n={n} d={d} k={k} kn={kn}) ==");
    let x = random_matrix(n, d, 7);
    let init = random_init(&x, k, 8);
    // Unseeded init: each run is one full n*k bootstrap assignment plus
    // three n*kn bounded assignment iterations — all sharded passes.
    let mut serial_median = None;
    for threads in [1usize, 2, 4, 8] {
        let cfg = Config {
            k,
            kn,
            max_iters: 3,
            record_trace: false,
            threads,
            ..Default::default()
        };
        let stats = h.run(&format!("k2means assign [{threads} thread(s)]"), || {
            let mut counter = OpCounter::default();
            k2means(&x, &init, &cfg, &mut counter)
        });
        match serial_median {
            None => serial_median = Some(stats.median),
            Some(t1) => println!(
                "    -> speedup vs 1 thread: {:.2}x",
                t1.as_secs_f64() / stats.median.as_secs_f64()
            ),
        }
    }

    // The cluster-sharded update step on the same workload.
    let labels: Vec<u32> = {
        let mut rng = Pcg32::seeded(9);
        (0..n).map(|_| rng.gen_below(k) as u32).collect()
    };
    let mut t1 = None;
    for threads in [1usize, 8] {
        let stats = h.run(&format!("update_means [{threads} thread(s)]"), || {
            let mut counter = OpCounter::default();
            update_means_threaded(&x, &labels, &init.centers, &mut counter, threads)
        });
        match t1 {
            None => t1 = Some(stats.median),
            Some(t) => println!(
                "    -> speedup vs 1 thread: {:.2}x",
                t.as_secs_f64() / stats.median.as_secs_f64()
            ),
        }
    }
    println!();
}

fn main() {
    let h = Harness { min_iters: 3, max_iters: 15, ..Default::default() };

    bench_sharded_engine(&h);

    println!("== native engine ==");
    let mut native = RustEngine;
    bench_engine(&h, "rust", &mut native);

    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        println!("\nSKIP xla engine: artifacts missing — run `make artifacts`");
        return;
    }
    println!("\n== xla-pjrt engine (AOT JAX+Pallas artifacts) ==");
    match XlaEngine::new(&dir) {
        Ok(mut xla) => bench_engine(&h, "xla", &mut xla),
        Err(e) => println!("SKIP xla engine: {e:#}"),
    }
}
