//! End-to-end algorithm benchmarks — one per paper table family:
//! wallclock *and* counted ops for every method on a fixed workload, so
//! the op-count speedups of Tables 5/6 can be sanity-checked against
//! real time (the paper's premise is that ops dominate runtime).
//!
//! `cargo bench --bench algorithms`

use k2m::bench::Harness;
use k2m::coordinator::{run_method, Method};
use k2m::data;

fn main() {
    let h = Harness { min_iters: 3, max_iters: 10, ..Default::default() };
    let ds = data::mnist50_like(0.02, 0xD5); // n≈1200, d=50
    let k = 100;
    println!("== algorithms on {} n={} d={} k={k} ==", ds.name, ds.n(), ds.d());
    println!(
        "{:<12}{:>14}{:>14}{:>10}{:>12}",
        "method", "median wall", "vector ops", "iters", "energy"
    );

    for method in Method::ALL {
        let param = 20; // mid-grid for AKM / k2-means
        let mut last = None;
        let stats = h.run(method.name(), || {
            let run = run_method(&ds.x, k, method, param, 0, 100, None);
            last = Some(run);
        });
        let run = last.unwrap();
        println!(
            "{:<12}{:>14?}{:>14.3e}{:>10}{:>12.4e}",
            method.name(),
            stats.median,
            run.total_ops,
            run.iters,
            run.energy
        );
    }

    // ops/sec consistency: wallclock per counted op should be similar
    // across Lloyd-family methods (validating the op-count methodology).
    println!("\n(ops/wallclock ratios validate that counted ops track real time)");
}
