//! Serving-path benchmark: queries/sec of the resident bounded-scan
//! query service ([`k2m::runtime::ServeService`]) against the full-scan
//! baseline ([`k2m::runtime::RustEngine::assign_with_model`]) on the
//! same trained [`ClusterModel`] — the train/serve split's throughput
//! story. Both answers are exact; the service's edge is how few of the
//! `k` centers it has to touch per query (the "evals/query" column).
//!
//! `cargo bench --bench serve`

use std::sync::Arc;

use k2m::bench::Harness;
use k2m::cluster::{ClusterModel, Config};
use k2m::coordinator::jobs::{run_job, JobAlgo, JobSpec};
use k2m::core::{Matrix, NumericsMode, OpCounter};
use k2m::runtime::{RustEngine, ServeService};
use k2m::testing::{blobs, random_matrix};

const N_TRAIN: usize = 20_000;
const K: usize = 256;
const D: usize = 32;
const KN: usize = 32;
const N_QUERIES: usize = 8_192;

/// Train the benchmark model once: k²-means (GDI init) on a blob
/// workload shaped like the paper's mid-size rows.
fn trained_model() -> ClusterModel {
    let (x, _) = blobs(N_TRAIN, K, D, 12.0, 3);
    let cfg = Config { k: K, kn: KN, m: 30, max_iters: 8, seed: 11, ..Default::default() };
    let out = run_job(&Arc::new(x), &JobSpec::new("bench", JobAlgo::K2Means, cfg));
    out.result.model
}

fn bench_queries(h: &Harness, model: &ClusterModel, qname: &str, q: &Matrix) {
    let n = q.rows();
    for nm in [NumericsMode::Strict, NumericsMode::Fast] {
        // Full-scan baseline: the engine's norm-trick assignment over
        // the model's cached center norms (always n x k pair work).
        let mut engine = RustEngine::with_numerics(nm);
        let s = h.run(&format!("full-scan [{qname}/{}]", nm.name()), || {
            engine.assign_with_model(q, model).unwrap()
        });
        println!("    -> {:.0} queries/s (baseline)", s.throughput(n as f64));

        for threads in [1usize, 4, 8] {
            let svc = ServeService::with_options(model.clone(), threads, nm);
            // One uncounted-timing pass to report the per-query bill
            // (identical across repeats: serving is deterministic).
            let mut ctr = OpCounter::default();
            svc.assign(q, &mut ctr);
            let evals = ctr.distances as f64 / n as f64;
            let s = h.run(&format!("serve assign [{qname}/{}/t{threads}]", nm.name()), || {
                let mut c = OpCounter::default();
                svc.assign(q, &mut c)
            });
            println!(
                "    -> {:.0} queries/s, {evals:.1} evals/query (full scan: {K}, {:.1}% saved)",
                s.throughput(n as f64),
                (1.0 - evals / K as f64) * 100.0
            );
        }
    }

    // Exact top-10 ranking throughput (strict tier, pool-wide).
    let svc = ServeService::with_options(model.clone(), 8, NumericsMode::Strict);
    let s = h.run(&format!("serve top-10 [{qname}/strict/t8]"), || {
        let mut c = OpCounter::default();
        svc.nearest_centers(q, 10, &mut c)
    });
    println!("    -> {:.0} queries/s", s.throughput(n as f64));
}

fn main() {
    println!("training the serve-bench model (k2means, n={N_TRAIN} k={K} d={D} kn={KN})...");
    let model = trained_model();
    let h = Harness { min_iters: 3, max_iters: 20, ..Default::default() };

    // In-distribution queries: the descent's coverage test accepts
    // often, so the bounded scan touches a small fraction of the
    // centers — the serving regime the split is built for.
    let (q_in, _) = blobs(N_QUERIES, K, D, 12.0, 4);
    println!("\n== in-distribution queries (n={N_QUERIES}) ==");
    bench_queries(&h, &model, "blob", &q_in);

    // Adversarial noise queries: coverage rarely proves out, most
    // queries fall through to the completion scan — the bounded scan's
    // floor (never worse than the full scan's bill).
    let q_noise = random_matrix(N_QUERIES / 2, D, 5);
    println!("\n== noise queries (n={}) ==", N_QUERIES / 2);
    bench_queries(&h, &model, "noise", &q_noise);
}
